//! Property-based tests (hand-rolled xorshift generator — proptest is
//! not in the offline vendor tree). Each property runs a few hundred
//! random cases; failures print the seed for reproduction.
//!
//! The QoS properties at the bottom drive the thread-free scheduler
//! core (`coordinator::qos::QosScheduler`) with injected clocks, so
//! WFQ share conformance, EDF ordering, the N-class aging bound and
//! the degrade ladder's floor/numerics are checked deterministically —
//! no timing, no sleeps.

use std::time::{Duration, Instant};

use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::coordinator::{DegradeLadder, DegradeLevel, QosClass, QosScheduler, TokenBucket};
use egpu_fft::coordinator::{FftRequest, FftService, ServiceConfig};
use egpu_fft::fft::sched::schedule;
use egpu_fft::fft::twiddle::{classify, twiddle, TwiddleKind};
use egpu_fft::fft::FftPlan;
use egpu_fft::isa::{asm::assemble, Inst, OpClass, Program, Reg};
use egpu_fft::sim::Sm;

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed | 1)
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
    fn reg(&mut self, max: Reg) -> Reg {
        (self.below(max as u64)) as Reg
    }
    fn f32(&mut self) -> f32 {
        ((self.next() >> 40) as f32 / (1u64 << 23) as f32) - 1.0
    }
}

/// A random straight-line program over a small register window and a
/// small shared-memory arena. Addresses are built from an `ldi`-seeded
/// base register so every access stays in bounds.
fn random_program(rng: &mut Rng, len: usize, regs: Reg, vm: bool) -> Program {
    let mut insts: Vec<Inst> = Vec::with_capacity(len + 2);
    // r1 holds a safe base address (0..32); data regs start at r2
    insts.push(Inst::Ldi { d: 1, imm: rng.below(32) as u32 });
    for _ in 0..len {
        let d = 2 + rng.reg(regs - 2);
        let a = 2 + rng.reg(regs - 2);
        let b = 2 + rng.reg(regs - 2);
        let choice = rng.below(if vm { 12 } else { 11 });
        let inst = match choice {
            0 => Inst::FAdd { d, a, b },
            1 => Inst::FSub { d, a, b },
            2 => Inst::FMul { d, a, b },
            3 => Inst::IAdd { d, a, b },
            4 => Inst::IXor { d, a, b },
            5 => Inst::IAndI { d, a, imm: rng.next() as u32 },
            6 => Inst::Mov { d, a, fp_work: false },
            7 => Inst::LdiF { d, imm: rng.f32() },
            8 => Inst::IShrI { d, a, sh: (rng.below(8) + 1) as u8 },
            9 => Inst::Lds { d, addr: 1, offset: rng.below(32) as i32 },
            10 => Inst::Sts { addr: 1, offset: rng.below(32) as i32, s: a },
            _ => Inst::StsBank { addr: 1, offset: rng.below(32) as i32, s: a },
        };
        insts.push(inst);
    }
    insts.push(Inst::Halt);
    Program::new("prop", insts)
}

fn cfg(variant: Variant, threads: usize) -> SmConfig {
    SmConfig {
        variant,
        n_sp: 16,
        pipeline_depth: 8,
        smem_words: 128,
        threads,
        regs_per_thread: 16,
    }
}

fn run_collect(p: &Program, variant: Variant, threads: usize, seed: u64) -> (Vec<u32>, Vec<u32>) {
    let mut sm = Sm::new(cfg(variant, threads));
    sm.seed_thread_ids();
    // deterministic initial memory
    let mut rng = Rng::new(seed);
    let init: Vec<u32> = (0..128).map(|_| rng.next() as u32).collect();
    sm.smem.host_fill(0, &init).unwrap();
    sm.run(p, threads).unwrap();
    let mem = sm.smem.host_read_bank(0, 0, 128);
    (sm.regs.clone(), mem)
}

/// PROPERTY: the list scheduler preserves program semantics — register
/// file and memory state are bit-identical after scheduling, for
/// hundreds of random programs (including save_bank on VM variants).
#[test]
fn scheduler_preserves_semantics() {
    for case in 0..300u64 {
        let mut rng = Rng::new(0xABCD + case);
        let vm = case % 3 == 0;
        let variant = if vm { Variant::DP_VM } else { Variant::DP };
        let p = random_program(&mut rng, 40, 14, vm);
        let s = schedule(&p, 8);
        assert_eq!(s.insts.len(), p.insts.len(), "case {case}");
        let threads = 16 << (case % 3); // 16/32/64
        let (r1, m1) = run_collect(&p, variant, threads, case);
        let (r2, m2) = run_collect(&s, variant, threads, case);
        assert_eq!(r1, r2, "registers differ, case {case}");
        assert_eq!(m1, m2, "memory differs, case {case}");
    }
}

/// PROPERTY: scheduling (a greedy heuristic) never increases total
/// cycles beyond a tiny slack, and never changes the non-NOP cycle mix.
#[test]
fn scheduler_never_hurts_cycles() {
    for case in 0..150u64 {
        let mut rng = Rng::new(0xBEEF + case);
        let p = random_program(&mut rng, 30, 12, false);
        let s = schedule(&p, 8);
        let threads = 16; // wavefront 1: max hazard exposure
        let total = |prog: &Program| {
            let mut sm = Sm::new(cfg(Variant::DP, threads));
            sm.seed_thread_ids();
            sm.run(prog, threads).unwrap().total()
        };
        let (t_orig, t_sched) = (total(&p), total(&s));
        // greedy list scheduling is not optimal; allow a few cycles of
        // slack but no systematic regression
        assert!(
            t_sched <= t_orig + t_orig / 20 + 4,
            "case {case}: {t_sched} > {t_orig}"
        );
        // non-NOP cycles are identical
        let classes = |prog: &Program| {
            let mut sm = Sm::new(cfg(Variant::DP, threads));
            sm.seed_thread_ids();
            let prof = sm.run(prog, threads).unwrap();
            prof.total() - prof.get(OpClass::Nop)
        };
        assert_eq!(classes(&p), classes(&s), "case {case}");
    }
}

/// PROPERTY: assembler round-trip — Display → assemble reproduces the
/// exact instruction sequence for random programs.
#[test]
fn assembler_round_trips_random_programs() {
    for case in 0..200u64 {
        let mut rng = Rng::new(0xF00D + case);
        let p = random_program(&mut rng, 50, 14, true);
        let text: String = p.insts.iter().map(|i| format!("{i}\n")).collect();
        let q = assemble("rt", &text).unwrap();
        assert_eq!(p.insts, q.insts, "case {case}");
    }
}

/// PROPERTY: `save_bank` + congruent-read = coherent-store semantics.
/// For any address pattern, reading from SP s after all 4 bank-copies
/// were written by SPs ≡ s (mod 4) gives the same result as sts.
#[test]
fn bank_write_congruent_read_equals_coherent() {
    for case in 0..100u64 {
        let mut rng = Rng::new(0xD00D + case);
        let threads = 16;
        let addr = rng.below(64) as i32;
        // every thread writes its id to (addr + tid) via each store kind
        let prog = |bank: bool| -> Program {
            let mut v = vec![Inst::IAddI { d: 2, a: 0, imm: addr }];
            v.push(if bank {
                Inst::StsBank { addr: 2, offset: 0, s: 0 }
            } else {
                Inst::Sts { addr: 2, offset: 0, s: 0 }
            });
            // read own location back (same SP wrote it: congruent)
            v.push(Inst::Lds { d: 3, addr: 2, offset: 0 });
            v.push(Inst::Halt);
            Program::new("bank", v)
        };
        let run = |p: &Program, variant: Variant| -> Vec<u32> {
            let mut sm = Sm::new(cfg(variant, threads));
            sm.seed_thread_ids();
            sm.run(p, threads).unwrap();
            (0..threads).map(|t| sm.regs[t * 16 + 3]).collect()
        };
        let via_bank = run(&prog(true), Variant::DP_VM);
        let via_coherent = run(&prog(false), Variant::DP);
        assert_eq!(via_bank, via_coherent, "case {case}");
    }
}

/// PROPERTY: plan digit reversal is a permutation and matches the
/// python-side `digit_reverse_indices` convention (involution base 4).
#[test]
fn plan_reversal_properties() {
    for (points, radix) in [
        (64usize, 2usize),
        (256, 2),
        (256, 4),
        (1024, 4),
        (4096, 4),
        (512, 8),
        (4096, 8),
        (256, 16),
        (1024, 16),
        (4096, 16),
    ] {
        let plan = FftPlan::new(points, radix, 1024).unwrap();
        let mut seen = vec![false; points];
        for i in 0..points {
            let r = plan.natural_of_inplace(i);
            assert!(!seen[r], "{points}/{radix}: duplicate {r}");
            seen[r] = true;
        }
        if plan.single_radix() {
            // single-radix reversal is an involution
            for i in 0..points {
                let r = plan.natural_of_inplace(i);
                assert_eq!(plan.natural_of_inplace(r), i, "{points}/{radix}");
            }
        }
    }
}

/// PROPERTY: twiddle classification is faithful — reconstructing the
/// rotation from the classified form reproduces the value.
#[test]
fn twiddle_classification_faithful() {
    for n in [4usize, 8, 16, 32, 64, 256, 1024] {
        for k in 0..n {
            let w = twiddle(n, k);
            let rebuilt = match classify(w) {
                TwiddleKind::One => twiddle(1, 0),
                TwiddleKind::MinusOne => twiddle(2, 1),
                TwiddleKind::MinusJ => twiddle(4, 1),
                TwiddleKind::PlusJ => twiddle(4, 3),
                TwiddleKind::EqualCoeff { mag, re_neg, im_neg } => {
                    egpu_fft::fft::Cpx::new(
                        if re_neg { -mag } else { mag },
                        if im_neg { -mag } else { mag },
                    )
                }
                TwiddleKind::Full(v) => v,
            };
            assert!(
                (rebuilt - w).abs() < 1e-9,
                "n={n} k={k}: {w:?} vs {rebuilt:?}"
            );
        }
    }
}

fn qos_sched(weights: &[u32], cap: usize, aging: Duration) -> QosScheduler<u64> {
    let classes: Vec<QosClass> = weights
        .iter()
        .enumerate()
        .map(|(i, &w)| QosClass::new(&format!("c{i}"), w))
        .collect();
    let caps = vec![cap; weights.len()];
    QosScheduler::new(classes, caps, aging)
}

/// PROPERTY (a): WFQ share conformance — under sustained saturation of
/// every class, each positive-weight class's served fraction is within
/// ε of weight/Σweights, for random weight vectors.
#[test]
fn qos_wfq_shares_converge_to_weight_fractions() {
    for case in 0..60u64 {
        let mut rng = Rng::new(0x0F51 + case);
        let n = 2 + (rng.below(4) as usize); // 2..=5 classes
        let weights: Vec<u32> = (0..n).map(|_| 1 + rng.below(6) as u32).collect();
        let mut s = qos_sched(&weights, 64, Duration::from_secs(3600));
        let t0 = Instant::now();
        let pops = 1200u64;
        let mut served = vec![0u64; n];
        for i in 0..pops {
            // keep every queue saturated: the property is about shares
            // under load, not arrival luck
            for c in 0..n {
                while s.depth(c) < 8 {
                    s.try_enqueue(c, None, t0, i).unwrap();
                }
            }
            let p = s.pop(t0).expect("saturated scheduler always pops");
            served[p.item.class] += 1;
        }
        let total_w: u32 = weights.iter().sum();
        for (c, &w) in weights.iter().enumerate() {
            let frac = served[c] as f64 / pops as f64;
            let want = w as f64 / total_w as f64;
            // DRR is exact to within one rotation of Σweights pops
            let eps = (total_w as f64 / pops as f64).max(0.02);
            assert!(
                (frac - want).abs() <= eps,
                "case {case}: class {c} share {frac:.4} vs {want:.4} (weights {weights:?})"
            );
        }
    }
}

/// PROPERTY (b): EDF ordering — within a class, no request is
/// dispatched while a queued peer of the same class holds an earlier
/// absolute deadline, across random interleavings of enqueues and pops.
#[test]
fn qos_edf_never_dispatches_past_an_earlier_deadline_peer() {
    for case in 0..120u64 {
        let mut rng = Rng::new(0xEDF0 + case);
        let n = 1 + (rng.below(3) as usize);
        let mut weights: Vec<u32> = (0..n).map(|_| rng.below(3) as u32).collect();
        if weights.iter().all(|&w| w == 0) {
            // at least one weighted class so DRR has a rotation
            weights = vec![1; n];
        }
        let mut s = qos_sched(&weights, 256, Duration::from_secs(3600));
        let t0 = Instant::now();
        // shadow copy of queued deadlines per class, keyed by seq
        let mut queued: Vec<Vec<(u64, Option<Instant>)>> = vec![Vec::new(); n];
        for step in 0..400u64 {
            if rng.below(3) < 2 {
                let c = (rng.below(n as u64)) as usize;
                let deadline = if rng.below(4) == 0 {
                    None
                } else {
                    Some(t0 + Duration::from_micros(rng.below(10_000)))
                };
                if let Ok(seq) = s.try_enqueue(c, deadline, t0, step) {
                    queued[c].push((seq, deadline));
                }
            } else if let Some(p) = s.pop(t0) {
                let c = p.item.class;
                let pos = queued[c]
                    .iter()
                    .position(|&(seq, _)| seq == p.item.seq)
                    .expect("popped item was queued");
                let (_, deadline) = queued[c].swap_remove(pos);
                if let Some(d) = deadline {
                    for &(seq, peer) in &queued[c] {
                        if let Some(pd) = peer {
                            assert!(
                                pd >= d,
                                "case {case} step {step}: dispatched deadline {d:?} \
                                 after queued peer seq {seq} with earlier {pd:?}"
                            );
                        }
                    }
                } else {
                    assert!(
                        queued[c].iter().all(|&(_, peer)| peer.is_none()),
                        "case {case} step {step}: deadline-less request dispatched \
                         while a deadlined peer waited"
                    );
                }
            }
        }
    }
}

/// PROPERTY (c): the aging bound holds with N classes — whatever the
/// weighted traffic, a background request is dispatched by the first
/// pop at or after its enqueue time plus the aging threshold.
#[test]
fn qos_aging_bound_holds_with_n_classes() {
    for case in 0..80u64 {
        let mut rng = Rng::new(0xA6E + case);
        let n_weighted = 1 + (rng.below(3) as usize);
        let mut weights: Vec<u32> = (0..n_weighted).map(|_| 1 + rng.below(5) as u32).collect();
        weights.push(0); // the background class under test
        let bg = weights.len() - 1;
        let aging = Duration::from_millis(1 + rng.below(50));
        let mut s = qos_sched(&weights, 64, aging);
        let t0 = Instant::now();
        for c in 0..n_weighted {
            for i in 0..8u64 {
                s.try_enqueue(c, None, t0, i).unwrap();
            }
        }
        s.try_enqueue(bg, None, t0, 999).unwrap();
        // pops strictly before the threshold serve weighted work only
        let before = t0 + aging - Duration::from_nanos(1);
        for _ in 0..3 {
            let p = s.pop(before).unwrap();
            assert_ne!(p.item.class, bg, "case {case}: promoted before the bound");
        }
        // the first pop at/after the threshold serves the aged request
        let after = t0 + aging;
        let p = s.pop(after).unwrap();
        assert_eq!(p.item.class, bg, "case {case}: aged request must win the slot");
        assert!(p.aged, "case {case}: the promotion is counted");
    }
}

/// PROPERTY (d): the degrade ladder never emits below `min_points`,
/// never deepens the requested level, and resolves exactly
/// `points >> shift` — for random points/floors/levels. The bitwise
/// part (degraded serving == serving the truncated signal) is pinned by
/// `qos_degraded_dispatch_is_bitwise_truncated_reference` below.
#[test]
fn qos_degrade_ladder_respects_the_floor() {
    let levels = [DegradeLevel::Full, DegradeLevel::Half, DegradeLevel::Quarter];
    for case in 0..300u64 {
        let mut rng = Rng::new(0x1ADD + case);
        let points = 1usize << (6 + rng.below(9)); // 64..16384
        let min_points = 1usize << (4 + rng.below(8)); // 16..2048
        let requested = levels[(rng.below(3)) as usize];
        let ladder = DegradeLadder { min_points };
        let (level, out) = ladder.apply(requested, points);
        assert!(level <= requested, "case {case}: clamp never deepens");
        assert_eq!(out, points >> level.shift(), "case {case}");
        if level != DegradeLevel::Full {
            assert!(
                out >= min_points,
                "case {case}: degraded below the floor ({out} < {min_points})"
            );
        }
        // the clamp is maximal: one step deeper would break the floor
        // (when a deeper step was requested and denied)
        if level < requested {
            assert!(
                points >> level.deeper().shift() < min_points,
                "case {case}: clamp was stricter than the floor requires"
            );
        }
    }
}

/// PROPERTY (d, numerics): a degraded dispatch is bitwise equal to
/// serving the truncated signal directly, at every ladder level — the
/// ladder changes dispatch, never numerics.
#[test]
fn qos_degraded_dispatch_is_bitwise_truncated_reference() {
    let svc = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
    let bits = |v: &[(f32, f32)]| -> Vec<(u32, u32)> {
        v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
    };
    for (points, level) in [
        (1024usize, DegradeLevel::Half),
        (1024, DegradeLevel::Quarter),
        (4096, DegradeLevel::Half),
        (4096, DegradeLevel::Quarter),
    ] {
        let input: Vec<(f32, f32)> = egpu_fft::fft::reference::test_signal(points, 77)
            .iter()
            .map(|c| c.to_f32_pair())
            .collect();
        let keep = points >> level.shift();
        let degraded = svc
            .request(FftRequest::new(input.clone()).with_level(level))
            .recv()
            .unwrap()
            .unwrap();
        let direct =
            svc.request(FftRequest::new(input[..keep].to_vec())).recv().unwrap().unwrap();
        assert_eq!(degraded.output.len(), keep);
        assert_eq!(
            bits(&degraded.output),
            bits(&direct.output),
            "{points} @ {level}: degraded output must be bitwise the truncated reference"
        );
    }
    svc.shutdown();
}

/// PROPERTY: the tenant token bucket starts full, refill is monotone
/// in `now`, saturates at the burst capacity, and a backwards clock
/// never drains tokens — for random rates and bursts. Clock-injected
/// like the scheduler core, so no timing or sleeps.
#[test]
fn tenant_bucket_refill_is_monotone_and_saturates() {
    for case in 0..200u64 {
        let mut rng = Rng::new(0xB0C4 + case);
        let rate = 1.0 + rng.below(10_000) as f64 / 10.0; // 1..=1001 Hz
        let burst = 1 + rng.below(48);
        let t0 = Instant::now();
        let mut b = TokenBucket::new(rate, burst, t0);
        let mut drained = 0u64;
        while b.try_take(t0) {
            drained += 1;
        }
        assert_eq!(drained, burst, "case {case}: bucket starts exactly full");
        let mut t_us = 0u64;
        let mut prev = b.available(t0);
        for step in 0..50 {
            t_us += rng.below(100_000); // forward jumps up to 100ms
            let now = t0 + Duration::from_micros(t_us);
            let avail = b.available(now);
            assert!(
                avail + 1e-9 >= prev,
                "case {case} step {step}: refill went backwards ({avail} < {prev})"
            );
            assert!(
                avail <= burst as f64 + 1e-9,
                "case {case} step {step}: refill past the burst cap ({avail} > {burst})"
            );
            // a clock reading from the past is ignored, not debited
            let back = b.available(t0);
            assert!(
                (back - avail).abs() < 1e-9,
                "case {case} step {step}: backwards clock changed the balance"
            );
            prev = avail;
        }
    }
}

/// PROPERTY: over any window `W` the bucket admits at most
/// `burst + rate × W` requests, under random interleavings of
/// same-instant call bursts and forward jumps — the rate-isolation
/// bound the tenancy layer (and the `tenants` bench gate) relies on.
#[test]
fn tenant_bucket_never_over_admits_the_window_bound() {
    for case in 0..200u64 {
        let mut rng = Rng::new(0x7E4A + case);
        let rate = rng.below(5000) as f64 / 5.0; // 0..1000 Hz, incl. 0
        let burst = 1 + rng.below(32);
        let t0 = Instant::now();
        let mut b = TokenBucket::new(rate, burst, t0);
        let mut t_us = 0u64;
        let mut admitted = 0u64;
        for _ in 0..400 {
            // about half the calls land on the same instant (a call
            // burst); the rest jump forward up to 20ms
            if rng.below(2) == 1 {
                t_us += rng.below(20_000);
            }
            if b.try_take(t0 + Duration::from_micros(t_us)) {
                admitted += 1;
            }
        }
        let window_s = t_us as f64 / 1e6;
        let bound = burst as f64 + rate * window_s;
        assert!(
            admitted as f64 <= bound + 1e-6,
            "case {case}: {admitted} admitted beyond burst {burst} + \
             rate {rate} × {window_s:.3}s = {bound:.2}"
        );
        if rate == 0.0 {
            assert!(admitted <= burst, "case {case}: zero-rate bucket admits only its burst");
        }
    }
}

/// PROPERTY: Goldilocks modular arithmetic satisfies the ring axioms,
/// checked against a u128 wide reference — for thousands of random
/// canonical elements. `reduce128` is additionally checked against the
/// plain `% p` on random 128-bit products, since the kernel's fast
/// reduction exploits the 2^64 − 2^32 + 1 structure rather than
/// dividing.
#[test]
fn goldilocks_ring_axioms_match_the_u128_reference() {
    use egpu_fft::fft::field::{self, P};
    let mut rng = Rng::new(0x601D);
    let elem = |rng: &mut Rng| rng.next() % P;
    for case in 0..2000u64 {
        let (a, b, c) = (elem(&mut rng), elem(&mut rng), elem(&mut rng));
        let wide = |x: u64, y: u64| ((x as u128 * y as u128) % P as u128) as u64;
        // closure + the u128 oracle
        assert_eq!(field::mulmod(a, b), wide(a, b), "case {case}: mul {a} {b}");
        assert_eq!(
            field::addmod(a, b),
            ((a as u128 + b as u128) % P as u128) as u64,
            "case {case}: add {a} {b}"
        );
        assert_eq!(
            field::submod(a, b),
            ((a as u128 + P as u128 - b as u128) % P as u128) as u64,
            "case {case}: sub {a} {b}"
        );
        // commutativity, associativity, distributivity
        assert_eq!(field::mulmod(a, b), field::mulmod(b, a), "case {case}");
        assert_eq!(
            field::mulmod(field::mulmod(a, b), c),
            field::mulmod(a, field::mulmod(b, c)),
            "case {case}"
        );
        assert_eq!(
            field::mulmod(a, field::addmod(b, c)),
            field::addmod(field::mulmod(a, b), field::mulmod(a, c)),
            "case {case}"
        );
        // identities and inverses
        assert_eq!(field::mulmod(a, 1), a, "case {case}");
        assert_eq!(field::addmod(a, 0), a, "case {case}");
        assert_eq!(field::addmod(a, field::submod(0, a)), 0, "case {case}");
        if a != 0 {
            assert_eq!(field::mulmod(a, field::invmod(a)), 1, "case {case}: inverse");
        }
        // reduce128 on a full-width random product
        let hi = rng.next();
        let lo = rng.next();
        let x = ((hi as u128) << 64) | lo as u128;
        assert_eq!(field::reduce128(x), (x % P as u128) as u64, "case {case}: reduce128");
    }
}

/// PROPERTY: the inverse NTT is a true inverse — `intt(ntt(x)) == x`
/// exactly, for random vectors at every power-of-two size the engine
/// serves single-pass (4..=4096), plus the root-of-unity structure the
/// transform relies on (order exactly n, w^(n/2) = −1).
#[test]
fn goldilocks_inverse_ntt_round_trips_exactly() {
    use egpu_fft::fft::field::{self, P};
    for case in 0..40u64 {
        let mut rng = Rng::new(0x17EE + case);
        let log_n = 2 + rng.below(11) as u32; // 4..=4096
        let n = 1usize << log_n;
        let x: Vec<u64> = (0..n).map(|_| rng.next() % P).collect();
        assert_eq!(field::intt(&field::ntt(&x)), x, "case {case}: n={n} round trip");
        let w = field::root_of_unity(log_n);
        assert_eq!(field::powmod(w, n as u64), 1, "case {case}: w^{n} = 1");
        assert_eq!(field::powmod(w, n as u64 / 2), P - 1, "case {case}: w^{{n/2}} = -1");
        for d in [2u64, 4, 8] {
            if (n as u64) > d {
                assert_ne!(field::powmod(w, n as u64 / d), 1, "case {case}: order exactly {n}");
            }
        }
    }
}

/// PROPERTY: the fast radix-2 NTT equals the naive O(N²) modular DFT at
/// the engine's single-pass sizes 256–4096 — the oracle the end-to-end
/// tests then carry to the full stack by transitivity.
#[test]
fn goldilocks_ntt_matches_the_naive_modular_dft() {
    use egpu_fft::fft::field;
    for (i, n) in [256usize, 512, 1024, 2048, 4096].into_iter().enumerate() {
        let x = field::test_elements(n, 0x0DF7 + i as u64);
        assert_eq!(field::ntt(&x), field::dft_naive(&x), "n={n}");
    }
}

/// PROPERTY: the convolution theorem holds — pointwise multiplication
/// in the NTT domain is exact cyclic convolution, checked against the
/// O(N²) schoolbook sum for random small vectors. This is the property
/// NTT consumers (polynomial multiplication, proof systems) actually
/// rely on, so it pins the transform's normalization end to end.
#[test]
fn goldilocks_ntt_convolution_theorem() {
    use egpu_fft::fft::field::{self, P};
    for case in 0..20u64 {
        let mut rng = Rng::new(0xC09 + case);
        let n = 1usize << (3 + rng.below(4)); // 8..=64
        let a: Vec<u64> = (0..n).map(|_| rng.next() % P).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.next() % P).collect();
        let fa = field::ntt(&a);
        let fb = field::ntt(&b);
        let prod: Vec<u64> =
            fa.iter().zip(&fb).map(|(&x, &y)| field::mulmod(x, y)).collect();
        let via_ntt = field::intt(&prod);
        let mut naive = vec![0u64; n];
        for i in 0..n {
            for j in 0..n {
                naive[(i + j) % n] =
                    field::addmod(naive[(i + j) % n], field::mulmod(a[i], b[j]));
            }
        }
        assert_eq!(via_ntt, naive, "case {case}: n={n} cyclic convolution");
    }
}

/// PROPERTY: cycle accounting is deterministic and data-independent —
/// two random inputs give identical profiles for any variant.
#[test]
fn profiles_data_independent_random() {
    for case in 0..30u64 {
        let variant = Variant::ALL6[(case % 6) as usize];
        let radix = [4usize, 8, 16][(case % 3) as usize];
        let points = 256;
        if variant.vm {
            let c = SmConfig::for_radix(variant, radix);
            let plan = FftPlan::new(points, radix, c.threads).unwrap();
            if !plan.passes.iter().any(|p| p.vm_eligible) {
                continue;
            }
        }
        let c = SmConfig::for_radix(variant, radix);
        let (p1, _) = egpu_fft::fft::validate(&c, points, radix, case).unwrap();
        let (p2, _) = egpu_fft::fft::validate(&c, points, radix, case + 1000).unwrap();
        assert_eq!(p1.cycles, p2.cycles, "case {case}");
    }
}
