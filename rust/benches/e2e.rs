//! Bench: end-to-end coordinator throughput and latency — the L3
//! §Perf targets (simulated-core scaling, PJRT fast-path throughput).
//!
//! `cargo bench --bench e2e` (requires `make artifacts` for the PJRT
//! sections; they are skipped with a warning otherwise)

mod harness;

use egpu_fft::coordinator::{Backend, FftService, ServiceConfig};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn main() {
    harness::section("simulated-core scaling (64 × fft1024, radix-16 VM+Complex)");
    let inputs: Vec<Vec<(f32, f32)>> = (0..64).map(|i| signal(1024, i)).collect();
    let mut base = None;
    for cores in [1usize, 2, 4, 8] {
        // start service outside the timed region (program generation +
        // SM allocation are setup, not serving)
        let svc = FftService::start(ServiceConfig {
            cores,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap();
        // warm every worker's program/SM cache
        svc.run_batch(inputs.clone()).unwrap();
        let r = harness::bench(&format!("sim_service_{cores}core_64xfft1024"), 1500, || {
            svc.run_batch(inputs.clone()).unwrap();
        });
        let jps = 64.0 / r.mean.as_secs_f64();
        if cores == 1 {
            base = Some(jps);
        }
        println!(
            "  {cores} cores: {:.0} jobs/s (scaling {:.2}x)",
            jps,
            jps / base.unwrap()
        );
        svc.shutdown();
    }

    if !std::path::Path::new("artifacts/fft1024.hlo.txt").exists() {
        eprintln!("WARNING: artifacts/ missing — PJRT benches skipped (run `make artifacts`)");
        return;
    }

    harness::section("PJRT fast path (steady state, post-compile)");
    for points in [256usize, 1024, 4096] {
        let svc = FftService::start(ServiceConfig {
            cores: 4,
            backend: Backend::Pjrt,
            ..Default::default()
        })
        .unwrap();
        let batch: Vec<Vec<(f32, f32)>> = (0..32).map(|i| signal(points, i)).collect();
        svc.run_batch(batch.clone()).unwrap(); // compile + warm
        let r = harness::bench(&format!("pjrt_service_32xfft{points}"), 1500, || {
            svc.run_batch(batch.clone()).unwrap();
        });
        println!("  fft{points}: {:.0} req/s", 32.0 / r.mean.as_secs_f64());
        svc.shutdown();
    }

    harness::section("validate path (PJRT + cycle-accurate cross-check)");
    let svc = FftService::start(ServiceConfig {
        cores: 4,
        backend: Backend::Validate,
        ..Default::default()
    })
    .unwrap();
    let batch: Vec<Vec<(f32, f32)>> = (0..16).map(|i| signal(1024, i)).collect();
    svc.run_batch(batch.clone()).unwrap();
    harness::bench("validate_service_16xfft1024", 1500, || {
        svc.run_batch(batch.clone()).unwrap();
    });
    let m = svc.metrics();
    println!(
        "  aggregate simulated efficiency: {:.2}% over {:.0} us of eGPU time",
        m.efficiency_pct(),
        m.virtual_us
    );
    svc.shutdown();
}
