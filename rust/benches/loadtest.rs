//! Bench: traffic-frontend serving capacity under open-loop overload.
//!
//! Each configuration floods a freshly started admission-controlled
//! frontend (4 shards, shed policy) with an offered rate far above
//! service capacity, so `achieved_rps` measures the sustainable
//! serving throughput — the number the CI `bench-gate` job regression-
//! checks against `BENCH_baseline.json`. Shed rate, deadline-miss rate
//! and the queue-wait / service-time p99s ride along in the JSON rows.
//!
//! ```sh
//! cargo bench --bench loadtest                      # full sweep
//! cargo bench --bench loadtest -- --quick           # CI-sized sweep
//! cargo bench --bench loadtest -- --json BENCH_loadtest.json
//! ```

use std::fmt::Write as _;
use std::time::Duration;

use egpu_fft::coordinator::{
    default_two_class, loadgen, AdmissionPolicy, ArrivalPattern, Backend, LoadReport,
    LoadgenConfig, ServerConfig, ServiceConfig, ServiceHandle, ShardPoolConfig, ShardedFftService,
    TrafficServer,
};

/// Start a frontend whose *backend* is already warm (plan cache built,
/// shard executors resident for every size). Warming goes through the
/// execution service directly, before the `TrafficServer` wraps it, so
/// the frontend's cumulative latency histograms — which `loadgen::run`
/// reports from — only ever see the measured run.
fn server(sizes: &[usize]) -> TrafficServer {
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards: 4,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    for &points in sizes {
        let warm: Vec<Vec<(f32, f32)>> = (0..8)
            .map(|i| {
                egpu_fft::fft::reference::test_signal(points, i as u64)
                    .iter()
                    .map(|c| c.to_f32_pair())
                    .collect()
            })
            .collect();
        svc.run_batch(warm).unwrap();
    }
    TrafficServer::start(
        ServiceHandle::Sharded(svc),
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(256)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 4,
            aging: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap()
}

struct Row {
    config: &'static str,
    report: LoadReport,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let duration = if quick { Duration::from_millis(1500) } else { Duration::from_secs(4) };
    let rate = 20_000.0; // far above capacity: achieved == sustainable
    let mixed = vec![256, 512, 1024, 2048, 4096];
    let configs: &[(&'static str, ArrivalPattern, Vec<usize>)] = &[
        ("poisson_fft1024", ArrivalPattern::Poisson, vec![1024]),
        ("poisson_mixed", ArrivalPattern::Poisson, mixed.clone()),
        ("burst_mixed", ArrivalPattern::Burst, mixed),
    ];

    println!(
        "\n=== loadtest capacity: {rate:.0} req/s offered for {:.1}s per config, shed policy{} ===",
        duration.as_secs_f64(),
        if quick { " (quick mode)" } else { "" }
    );

    let mut rows: Vec<Row> = Vec::new();
    for (config, pattern, sizes) in configs {
        let srv = server(sizes);
        let report = loadgen::run(
            &srv,
            &LoadgenConfig {
                pattern: *pattern,
                rate_hz: rate,
                duration,
                sizes: sizes.clone(),
                deadline: Some(Duration::from_millis(25)),
                ..Default::default()
            },
        );
        assert!(report.accounted, "{config}: every request must be answered");
        println!(
            "  {config:<16} achieved {:>7.0} rps (offered {:.0}), shed {:.1}%, \
             miss {:.1}%, q-p99 {:.0}us, s-p99 {:.0}us",
            report.achieved_rps,
            report.offered_rps,
            100.0 * report.shed_rate,
            100.0 * report.deadline_miss_rate,
            report.queue_wait_us[2],
            report.service_time_us[2]
        );
        rows.push(Row { config: *config, report });
        srv.shutdown();
    }

    let geomean = rows
        .iter()
        .map(|r| r.report.achieved_rps.max(1e-9).ln())
        .sum::<f64>()
        / rows.len() as f64;
    println!("\naggregate achieved throughput (geomean): {:.0} rps", geomean.exp());

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let rep = &r.report;
            let _ = write!(
                json,
                "  {{\"bench\": \"loadtest\", \"config\": \"{}\", \"pattern\": \"{}\", \
                 \"achieved_rps\": {:.1}, \"offered_rps\": {:.1}, \"shed_rate\": {:.4}, \
                 \"deadline_miss_rate\": {:.4}, \"queue_p99_us\": {:.1}, \
                 \"service_p99_us\": {:.1}, \"quick\": {}}}{}\n",
                r.config,
                rep.pattern,
                rep.achieved_rps,
                rep.offered_rps,
                rep.shed_rate,
                rep.deadline_miss_rate,
                rep.queue_wait_us[2],
                rep.service_time_us[2],
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
