//! Bench: regenerate the paper's Tables 1–4 (the full §6 profiling
//! campaign) and print the reproduced rows next to the paper's values.
//!
//! `cargo bench --bench tables`

mod harness;

use egpu_fft::report;

/// Paper values for the spot-check rows (points, variant-index, total,
/// time_us, efficiency%) — variant index in ALL6 order.
const PAPER_T1_4096: &[(usize, u64, f64, f64)] = &[
    (0, 86817, 112.60, 15.48), // DP
    (1, 62214, 80.73, 21.60),  // DP-VM
    (3, 59361, 76.99, 22.64),  // DP-VM-Complex
    (4, 62241, 103.74, 21.59), // QP
];

fn main() {
    harness::section("Table 1: radix-4 campaign (sizes 256/1024/4096 × 6 variants)");
    let mut t1 = None;
    harness::bench("table1_radix4_campaign", 1500, || {
        t1 = Some(report::profile_table(4).unwrap());
    });
    let t1 = t1.unwrap();
    println!("\n{}", t1.render_markdown());
    println!("paper spot-checks (radix-4, 4096 points):");
    let row = &t1.rows.iter().find(|(p, _)| *p == 4096).unwrap().1;
    for &(vi, total, time, eff) in PAPER_T1_4096 {
        let got = row[vi].as_ref().unwrap();
        println!(
            "  variant#{vi}: total {} (paper {total}), time {:.2}us (paper {time}), \
             eff {:.2}% (paper {eff}%)",
            got.total(),
            got.time_us(),
            got.efficiency_pct()
        );
    }

    harness::section("Table 2: radix-8 campaign");
    let mut t2 = None;
    harness::bench("table2_radix8_campaign", 1000, || {
        t2 = Some(report::profile_table(8).unwrap());
    });
    println!("\n{}", t2.unwrap().render_markdown());

    harness::section("Table 3: radix-16 campaign");
    let mut t3 = None;
    harness::bench("table3_radix16_campaign", 1000, || {
        t3 = Some(report::profile_table(16).unwrap());
    });
    let t3 = t3.unwrap();
    println!("\n{}", t3.render_markdown());
    println!(
        "best 4096-pt efficiency: {:.2}% (paper: 35.69% — see EXPERIMENTS.md on the\n\
         paper's Table-3 VM/QP store-row swap)",
        t3.best_efficiency(4096).unwrap()
    );

    harness::section("Table 4: radix-8 butterfly breakdown");
    harness::bench("table4_butterfly", 200, || {
        let _ = report::table4();
    });
    println!("\n{}", report::render_table4());
}
