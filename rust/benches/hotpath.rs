//! Bench: dispatch overhead per job on the zero-copy hot path,
//! excluding FFT compute.
//!
//! Every configuration runs the service with [`Backend::Noop`], whose
//! workers skip the simulator entirely and reply with the input slot
//! unchanged, so the measured ns/job is pure coordination cost: arena
//! lease + memcpy, enqueue, worker wake, reply channel, slot release.
//! The run **panics** unless every job's payload came from an arena
//! lease hit (`lease_hits` delta == jobs, `lease_misses` delta == 0) —
//! the zero-allocation acceptance assertion for the lease-hit path.
//!
//! ```sh
//! cargo bench --bench hotpath                      # full run
//! cargo bench --bench hotpath -- --quick           # CI-sized run
//! cargo bench --bench hotpath -- --json BENCH_hotpath.json
//! ```

use std::fmt::Write as _;
use std::sync::mpsc::Receiver;
use std::time::Instant;

use anyhow::Result;
use egpu_fft::coordinator::{
    Backend, FftRequest, FftResult, FftService, JobArena, ServiceConfig, ShardPoolConfig,
    ShardedFftService,
};
use egpu_fft::fft::reference;

const POINTS: usize = 1024;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

struct Row {
    config: &'static str,
    ns_per_job: f64,
    jobs: usize,
    lease_hits: u64,
}

/// Drive `jobs` sequential no-op requests through `request`, timing the
/// round-trips and auditing the arena counters across the window.
fn measure(
    config: &'static str,
    jobs: usize,
    proto: &[(f32, f32)],
    request: impl Fn(FftRequest) -> Receiver<Result<FftResult>>,
) -> Row {
    // Warm outside the window: thread wake-up, channel setup — and the
    // one place the echo contract itself is checked, so the timed loop
    // below is pure dispatch.
    for _ in 0..32 {
        let slot = JobArena::global().lease_copy(proto);
        let r = request(FftRequest::with_input_slot(slot)).recv().unwrap().unwrap();
        assert_eq!(&r.output[..], proto, "noop backend must echo the input");
    }
    let before = JobArena::global().snapshot();
    let t0 = Instant::now();
    for _ in 0..jobs {
        let slot = JobArena::global().lease_copy(proto);
        let r = request(FftRequest::with_input_slot(slot)).recv().unwrap().unwrap();
        debug_assert_eq!(r.output.len(), proto.len());
    }
    let elapsed = t0.elapsed();
    let after = JobArena::global().snapshot();
    let hits = after.lease_hits - before.lease_hits;
    let misses = after.lease_misses - before.lease_misses;
    assert_eq!(
        hits, jobs as u64,
        "{config}: every job must lease its payload buffer from the arena (zero-alloc path)"
    );
    assert_eq!(misses, 0, "{config}: no job may fall back to a heap allocation");
    let ns_per_job = elapsed.as_secs_f64() * 1e9 / jobs as f64;
    println!("  {config}: {ns_per_job:.0} ns/job over {jobs} jobs ({hits} lease hits)");
    Row { config, ns_per_job, jobs, lease_hits: hits }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let jobs = if quick { 2_000 } else { 20_000 };

    println!(
        "\n=== hot path: dispatch overhead per job, no-op backend ({POINTS}-point payloads){} ===",
        if quick { " (quick mode)" } else { "" }
    );
    let proto = signal(POINTS, 7);
    let mut rows: Vec<Row> = Vec::new();

    {
        let svc = FftService::start(ServiceConfig {
            cores: 2,
            backend: Backend::Noop,
            ..Default::default()
        })
        .unwrap();
        rows.push(measure("pool2_noop", jobs, &proto, |req| svc.request(req)));
        svc.shutdown();
    }
    {
        let svc = ShardedFftService::start(ShardPoolConfig {
            shards: 2,
            steal_threshold: 0,
            service: ServiceConfig { backend: Backend::Noop, ..Default::default() },
            ..Default::default()
        })
        .unwrap();
        rows.push(measure("shard2_noop", jobs, &proto, |req| svc.request(req)));
        svc.shutdown();
    }

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let _ = write!(
                json,
                "  {{\"bench\": \"hotpath\", \"config\": \"{}\", \"ns_per_job\": {:.1}, \
                 \"jobs\": {}, \"lease_hits\": {}, \"quick\": {}}}{}\n",
                r.config,
                r.ns_per_job,
                r.jobs,
                r.lease_hits,
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
