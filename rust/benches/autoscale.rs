//! Bench: SLO-driven shard autoscaling under a step overload.
//!
//! Each configuration starts the sharded service at ONE shard behind
//! the admission-controlled frontend, attaches the autoscale
//! controller, and offers a two-phase open-loop load: a healthy
//! baseline rate (~0.5× single-shard capacity, measured on this host),
//! then a step to ~1.5× single-shard capacity — more than one shard
//! can serve, less than the scaled-up pool can. The bench reports
//! shards-over-time, the p99/shed recovery time after the step, and
//! the shed rate before vs after the controller reacts. `recovered_rps`
//! (phase-2 achieved throughput) plus the `shed_rate_after` /
//! `p99_recovery_ms` columns are what the CI `bench-gate` job
//! regression-checks against `BENCH_baseline.json`.
//!
//! ```sh
//! cargo bench --bench autoscale                      # full sweep
//! cargo bench --bench autoscale -- --quick           # CI-sized sweep
//! cargo bench --bench autoscale -- --json BENCH_autoscale.json
//! ```

use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use egpu_fft::coordinator::{
    default_two_class, loadgen, AdmissionPolicy, ArrivalPattern, AutoscaleController,
    AutoscalePolicy, Backend, LoadgenConfig, PressureSample, ServerConfig, ServiceConfig,
    ServiceHandle, ShardPoolConfig, ShardedFftService, TrafficServer,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

/// Measured single-shard fft1024 serving capacity on this host,
/// jobs/s — the anchor that keeps the offered step meaningful on fast
/// and slow runners alike (shared library helper, so every calibrated
/// bench and test measures capacity the same way).
fn calibrate_single_shard_rps() -> f64 {
    ShardedFftService::calibrate_single_shard_rps(1024).unwrap()
}

struct Row {
    config: &'static str,
    recovered_rps: f64,
    shed_rate_before: f64,
    shed_rate_after: f64,
    p99_recovery_ms: f64,
    shards_final: usize,
    scale_ups: usize,
}

fn run_config(
    config: &'static str,
    pattern: ArrivalPattern,
    base_rps: f64,
    phase: Duration,
    max_shards: usize,
) -> Row {
    let policy = AutoscalePolicy {
        min_shards: 1,
        max_shards,
        target_p99_ms: 25.0,
        max_shed_rate: 0.02,
        scale_up_cooldown: Duration::from_millis(100),
        scale_down_cooldown: Duration::from_secs(10), // never down mid-bench
        interval: Duration::from_millis(25),
        ..Default::default()
    };
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards: 1,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    svc.run_batch((0..8).map(|i| signal(1024, i)).collect()).unwrap(); // warm
    let server = TrafficServer::start(
        ServiceHandle::Sharded(svc),
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(256)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: (2 * max_shards).max(4),
            ..Default::default()
        },
    )
    .unwrap();
    let controller = AutoscaleController::spawn(&server, policy.clone()).unwrap();

    let mut meter = server.pressure_meter();
    let done = AtomicBool::new(false);
    let (step_tx, step_rx) = channel::<Instant>();
    let (report_tx, report_rx) = channel();
    let mut samples: Vec<(Instant, PressureSample)> = Vec::new();
    std::thread::scope(|scope| {
        let server = &server;
        let done = &done;
        scope.spawn(move || {
            let lg = |rate_hz: f64| LoadgenConfig {
                pattern,
                rate_hz,
                duration: phase,
                sizes: vec![1024],
                deadline: None,
                ..Default::default()
            };
            let baseline = loadgen::run(server, &lg(0.5 * base_rps));
            assert!(baseline.accounted, "{config}: baseline phase must account all requests");
            step_tx.send(Instant::now()).unwrap();
            let step = loadgen::run(server, &lg(1.5 * base_rps));
            assert!(step.accounted, "{config}: step phase must account all requests");
            report_tx.send(step).unwrap();
            done.store(true, Ordering::Release);
        });
        while !done.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(25));
            samples.push((Instant::now(), meter.sample()));
        }
    });
    let step_at = step_rx.recv().expect("step instant sent");
    let report = report_rx.recv().expect("step-phase report sent");
    let log = controller.stop();

    let since_step = |t: Instant| t.checked_duration_since(step_at).map(|d| d.as_secs_f64());
    // worst shedding in the first 300ms after the step, before the
    // controller has had time to act
    let shed_rate_before = samples
        .iter()
        .filter(|(t, _)| matches!(since_step(*t), Some(s) if s <= 0.3))
        .map(|(_, s)| s.shed_rate)
        .fold(0.0f64, f64::max);
    // steady state: the last quarter of the step phase
    let tail: Vec<f64> = samples
        .iter()
        .filter(|(t, _)| matches!(since_step(*t), Some(s) if s >= 0.75 * phase.as_secs_f64()))
        .map(|(_, s)| s.shed_rate)
        .collect();
    let shed_rate_after = if tail.is_empty() {
        1.0
    } else {
        tail.iter().sum::<f64>() / tail.len() as f64
    };
    // Recovery: the overload takes a moment to manifest after the step
    // (the first post-step samples still cover baseline traffic), so
    // find the first post-step sample that *violates* the SLO, then the
    // first compliant sample after it. No violation at all means the
    // step never breached the SLO (recovery 0); a violation that never
    // clears caps at the phase duration.
    let slo_ok = |s: &PressureSample| {
        s.shed_rate <= policy.max_shed_rate && s.queue_p99_us / 1e3 <= policy.target_p99_ms
    };
    let violation_at = samples
        .iter()
        .find_map(|(t, s)| since_step(*t).filter(|_| !slo_ok(s)));
    let p99_recovery_ms = match violation_at {
        None => 0.0,
        Some(v) => samples
            .iter()
            .find_map(|(t, s)| since_step(*t).filter(|at| *at > v && slo_ok(s)))
            .map_or(phase.as_secs_f64() * 1e3, |s| s * 1e3),
    };

    let shards_final = log.samples.last().map_or(1, |s| s.shards);
    let scale_ups = log.events.iter().filter(|e| e.to_shards > e.from_shards).count();
    println!(
        "  {config:<16} recovered {:>7.0} rps, shed {:.1}% -> {:.1}%, \
         p99 recovery {:.0} ms, shards 1 -> {shards_final} ({scale_ups} up)",
        report.achieved_rps,
        100.0 * shed_rate_before,
        100.0 * shed_rate_after,
        p99_recovery_ms
    );
    print!("{}", log.render());
    server.shutdown();
    Row {
        config,
        recovered_rps: report.achieved_rps,
        shed_rate_before,
        shed_rate_after,
        p99_recovery_ms,
        shards_final,
        scale_ups,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let (phase, max_shards) = if quick {
        (Duration::from_millis(1500), 4)
    } else {
        (Duration::from_secs(4), 8)
    };
    let base_rps = calibrate_single_shard_rps();
    println!(
        "\n=== autoscale step-overload: 1 shard (capacity ~{base_rps:.0} rps) offered \
         0.5x then 1.5x capacity, {:.1}s per phase, max {max_shards} shards{} ===",
        phase.as_secs_f64(),
        if quick { " (quick mode)" } else { "" }
    );

    let configs: &[(&'static str, ArrivalPattern)] = if quick {
        &[("poisson_step", ArrivalPattern::Poisson)]
    } else {
        &[
            ("poisson_step", ArrivalPattern::Poisson),
            ("burst_step", ArrivalPattern::Burst),
        ]
    };
    let rows: Vec<Row> = configs
        .iter()
        .map(|&(c, p)| run_config(c, p, base_rps, phase, max_shards))
        .collect();

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let _ = write!(
                json,
                "  {{\"bench\": \"autoscale\", \"config\": \"{}\", \
                 \"recovered_rps\": {:.1}, \"shed_rate_before\": {:.4}, \
                 \"shed_rate_after\": {:.4}, \"p99_recovery_ms\": {:.1}, \
                 \"shards_final\": {}, \"scale_ups\": {}, \"max_shards\": {}, \
                 \"quick\": {}}}{}\n",
                r.config,
                r.recovered_rps,
                r.shed_rate_before,
                r.shed_rate_after,
                r.p99_recovery_ms,
                r.shards_final,
                r.scale_ups,
                max_shards,
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
