//! Bench: multi-backend routing — routed vs pinned throughput, router
//! overhead, and the cost of validation sampling.
//!
//! The alternate lane is synthetic and calibrated against this host's
//! *measured* simulator service time (it serves the f64 reference
//! transform after sleeping a quarter of the sim time), so "4x faster
//! lane" means the same thing on fast and slow runners. Scenarios:
//!
//! * **pinned_sim** — the unrouted pool service: the pre-routing
//!   baseline every other row is compared against.
//! * **routed_sim_only** — the same pool behind a [`BackendSet`] with
//!   no alternates: pure router overhead, which must be noise.
//! * **routed_fastpath** — the 4x lane registered; the router must
//!   send it at least 90% of steady-state traffic (asserted, so the
//!   bench run itself hard-gates the routing acceptance criterion).
//! * **validate_1pct / validate_10pct** — the 4x lane with validation
//!   sampling at 1% / 10%; `validate_overhead` is the throughput
//!   fraction lost vs `routed_fastpath` (every sampled request pays a
//!   full simulator re-serve).
//!
//! ```sh
//! cargo bench --bench backend                  # full sweep
//! cargo bench --bench backend -- --quick       # CI-sized sweep
//! cargo bench --bench backend -- --json BENCH_backend.json
//! ```

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use egpu_fft::coordinator::{
    BackendSet, BackendSetConfig, FftBackend, FftService, ServiceConfig, ServiceHandle,
};
use egpu_fft::fft::{reference, Cpx};

const POINTS: usize = 1024;
const CORES: usize = 2;
const WORKERS: usize = 4;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

/// A synthetic fast lane: correct output (the f64 reference transform)
/// delivered in a fixed fraction of the measured simulator time.
struct FastPath {
    sleep: Duration,
}

impl FftBackend for FastPath {
    fn name(&self) -> &str {
        "fastpath"
    }

    fn fft(&self, input: &[(f32, f32)]) -> anyhow::Result<Vec<(f32, f32)>> {
        std::thread::sleep(self.sleep);
        let cpx: Vec<Cpx> = input
            .iter()
            .map(|&(r, i)| Cpx::new(r as f64, i as f64))
            .collect();
        Ok(reference::fft(&cpx).iter().map(|c| c.to_f32_pair()).collect())
    }
}

fn pool() -> ServiceHandle {
    ServiceHandle::Pool(
        FftService::start(ServiceConfig { cores: CORES, ..Default::default() }).unwrap(),
    )
}

/// Measured steady-state simulator service time for [`POINTS`], µs.
fn calibrate_sim_us() -> f64 {
    let probe = FftService::start(ServiceConfig { cores: 1, ..Default::default() }).unwrap();
    let mut us: f64 = 0.0;
    for seed in 0..3 {
        let r = probe.run_batch(vec![signal(POINTS, seed)]).unwrap();
        us = r[0].wall_us; // keep the last (warmed) sample
    }
    probe.shutdown();
    us.max(100.0)
}

fn build_set(fraction: f64, fastpath: Option<Duration>) -> BackendSet {
    let mut set = BackendSet::new(
        pool(),
        BackendSetConfig {
            validate_fraction: fraction,
            calibrate_sizes: vec![POINTS],
            ..Default::default()
        },
    )
    .unwrap();
    if let Some(sleep) = fastpath {
        set.register("fastpath", Box::new(FastPath { sleep }), WORKERS).unwrap();
    }
    set.calibrate().unwrap();
    set
}

/// Serve `requests` through the set and return (rps, fastpath share,
/// validate checks, validate mismatches).
fn run_routed(set: &BackendSet, requests: usize) -> (f64, f64, u64, u64) {
    let inputs: Vec<_> = (0..requests).map(|i| signal(POINTS, i as u64)).collect();
    let t0 = Instant::now();
    let results = set.run_batch(inputs, WORKERS).unwrap();
    let rps = results.len() as f64 / t0.elapsed().as_secs_f64();
    let stats = set.stats();
    let total: u64 = stats.iter().map(|s| s.served).sum();
    let fast = stats.iter().find(|s| s.name == "fastpath");
    let share = match (fast, total) {
        (Some(f), t) if t > 0 => f.served as f64 / t as f64,
        _ => 0.0,
    };
    let checks: u64 = stats.iter().map(|s| s.validate_checks).sum();
    let mismatches: u64 = stats.iter().map(|s| s.validate_mismatches).sum();
    assert_eq!(mismatches, 0, "an honest lane must never mismatch: {stats:?}");
    (rps, share, checks, mismatches)
}

struct Row {
    config: String,
    routed_rps: f64,
    validate_overhead: f64,
    fastpath_share: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let requests = if quick { 48 } else { 240 };

    let sim_us = calibrate_sim_us();
    let fast = Duration::from_secs_f64(sim_us / 4.0 / 1e6);
    println!(
        "\n=== backend: routed vs pinned fft{POINTS} (sim ~{sim_us:.0}us/req, synthetic \
         fast lane at 1/4x{}) ===",
        if quick { ", quick mode" } else { "" }
    );

    let mut rows = Vec::new();

    // pinned_sim: the unrouted pool — the pre-routing baseline
    let svc = FftService::start(ServiceConfig { cores: CORES, ..Default::default() }).unwrap();
    svc.run_batch((0..4).map(|i| signal(POINTS, i)).collect()).unwrap(); // warm
    let inputs: Vec<_> = (0..requests).map(|i| signal(POINTS, i as u64)).collect();
    let t0 = Instant::now();
    let served = svc.run_batch(inputs).unwrap();
    let pinned_rps = served.len() as f64 / t0.elapsed().as_secs_f64();
    svc.shutdown();
    rows.push(Row {
        config: "pinned_sim".into(),
        routed_rps: pinned_rps,
        validate_overhead: 0.0,
        fastpath_share: 0.0,
    });

    // routed_sim_only: router in the path, nothing to route to
    let set = build_set(0.0, None);
    let (rps, _, _, _) = run_routed(&set, requests);
    set.shutdown();
    rows.push(Row {
        config: "routed_sim_only".into(),
        routed_rps: rps,
        validate_overhead: 0.0,
        fastpath_share: 0.0,
    });

    // routed_fastpath: the 4x lane must win ≥90% of the traffic
    let set = build_set(0.0, Some(fast));
    let (base_rps, share, _, _) = run_routed(&set, requests);
    set.shutdown();
    assert!(
        share >= 0.9,
        "router must send >=90% of steady-state traffic to the 4x lane (got {share:.2})"
    );
    assert!(
        base_rps > pinned_rps,
        "routing to a 4x lane must beat the pinned pool ({base_rps:.0} vs {pinned_rps:.0} rps)"
    );
    rows.push(Row {
        config: "routed_fastpath".into(),
        routed_rps: base_rps,
        validate_overhead: 0.0,
        fastpath_share: share,
    });

    // validation sampling: throughput fraction lost vs routed_fastpath
    for (label, fraction) in [("validate_1pct", 0.01), ("validate_10pct", 0.1)] {
        let set = build_set(fraction, Some(fast));
        let (rps, share, checks, _) = run_routed(&set, requests);
        set.shutdown();
        assert!(
            checks > 0 || requests < (1.0 / fraction) as usize,
            "{label}: sampling at {fraction} over {requests} requests never fired"
        );
        rows.push(Row {
            config: label.into(),
            routed_rps: rps,
            validate_overhead: (1.0 - rps / base_rps).max(0.0),
            fastpath_share: share,
        });
    }

    println!(
        "\n  {:<18} {:>12} {:>18} {:>15}",
        "config", "routed_rps", "validate_overhead", "fastpath_share"
    );
    for r in &rows {
        println!(
            "  {:<18} {:>12.0} {:>18.3} {:>15.2}",
            r.config, r.routed_rps, r.validate_overhead, r.fastpath_share
        );
    }

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let _ = write!(
                json,
                "  {{\"bench\": \"backend\", \"config\": \"{}\", \"routed_rps\": {:.1}, \
                 \"validate_overhead\": {:.4}, \"fastpath_share\": {:.4}, \
                 \"quick\": {}}}{}\n",
                r.config,
                r.routed_rps,
                r.validate_overhead,
                r.fastpath_share,
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
