//! Bench: Goldilocks NTT serving throughput through the full stack —
//! the second workload the CI gate holds to a floor.
//!
//! Three legs, each emitting one JSON row with an `ntt_rps` field (the
//! gate takes the geometric mean across rows against
//! `ntt.agg_ntt_rps` in `BENCH_baseline.json`):
//!
//! * **saturated 1024 / 4096**: open-loop `ntt` loadgen mix against a
//!   fresh two-shard server, offered ~1.5x the host kernel's measured
//!   capacity so the achieved rate reads serving capacity, not arrival
//!   luck. Admission, QoS, tenancy and sharded dispatch are all in the
//!   measured path.
//! * **multipass 65536**: sequential above-ceiling requests through the
//!   sharded service — each decomposes 256 × 256 through the four-step
//!   orchestration, so the row meters the staged path end to end.
//!
//! Every leg hard-asserts exactness on a sampled request (the output
//! must equal the host kernel integer for integer) — a bench that
//! serves wrong answers fast must fail CI, not ratchet the baseline.
//!
//! ```sh
//! cargo bench --bench ntt                      # full run
//! cargo bench --bench ntt -- --quick           # CI-sized run
//! cargo bench --bench ntt -- --json BENCH_ntt.json
//! ```

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use egpu_fft::coordinator::{
    loadgen, AdmissionPolicy, Backend, FftCompute, FftRequest, LoadgenConfig, ServerConfig,
    ServiceConfig, ServiceHandle, ShardPoolConfig, ShardedFftService, TenantSpec, TrafficServer,
};
use egpu_fft::fft::field;

fn sharded(shards: usize) -> ShardedFftService {
    ShardedFftService::start(ShardPoolConfig {
        shards,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap()
}

/// Measured host-kernel NTT rate at `points`, transforms/s — the
/// calibration anchor that keeps "saturated" meaning the same thing on
/// fast and slow runners.
fn calibrate_host_ntt_rps(points: usize) -> f64 {
    let x = field::test_elements(points, 7);
    let iters = 100u32;
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(field::ntt(std::hint::black_box(&x)));
    }
    iters as f64 / t0.elapsed().as_secs_f64()
}

/// Hard exactness check through whatever `compute` serves: one request,
/// integer equality against the standalone host kernel.
fn assert_exact(compute: &dyn FftCompute, points: usize, seed: u64) {
    let input = field::test_elements(points, seed);
    let r = compute
        .request(FftRequest::ntt(input.clone()))
        .recv()
        .unwrap()
        .expect("NTT request served");
    let got: Vec<u64> = r.output.iter().map(|&w| field::unpack(w)).collect();
    assert_eq!(got, field::ntt(&input), "{points}-point NTT served inexactly");
}

/// One saturated open-loop leg at a single transform size: offered rate
/// is 1.5x the calibrated two-shard capacity, Shed admission absorbs
/// the overload, and the achieved completion rate is the row's
/// `ntt_rps`.
fn run_saturated(points: usize, duration: Duration) -> (f64, u64, u64) {
    let svc = sharded(2);
    assert_exact(&svc, points, 0xBE);
    let host_rps = calibrate_host_ntt_rps(points);
    let offered = 1.5 * 2.0 * host_rps;
    let server = TrafficServer::start(
        ServiceHandle::Sharded(svc),
        ServerConfig {
            policy: AdmissionPolicy::Shed,
            dispatchers: 4,
            tenants: vec![TenantSpec::new("prover", 1e9, 1_000_000)],
            ..Default::default()
        },
    )
    .unwrap();
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: offered,
            duration,
            sizes: vec![points],
            tenant_mix: vec![offered],
            ..LoadgenConfig::ntt()
        },
    );
    println!("-- saturated ntt{points} (host kernel ~{host_rps:.0} rps/core) --");
    print!("{}", report.render());
    assert!(report.accounted, "ntt{points}: every request must be answered");
    assert!(report.completed > 0, "ntt{points}: saturated run served nothing");
    server.shutdown();
    (report.achieved_rps, report.completed, report.shed)
}

/// The multipass leg: `count` sequential 65536-point requests, each
/// decomposing 256 × 256 through the four-step orchestration.
fn run_multipass(count: u32) -> (f64, u64) {
    let svc = sharded(2);
    let input = field::test_elements(65_536, 0xAB);
    let want = field::ntt(&input);
    let t0 = Instant::now();
    for i in 0..count {
        let r = svc
            .request(FftRequest::ntt(input.clone()))
            .recv()
            .unwrap()
            .expect("multipass NTT served");
        if i == 0 {
            let got: Vec<u64> = r.output.iter().map(|&w| field::unpack(w)).collect();
            assert_eq!(got, want, "65536-point multipass NTT served inexactly");
        }
    }
    let rps = count as f64 / t0.elapsed().as_secs_f64();
    let stage_jobs = svc.metrics().multipass.stage_jobs();
    println!("-- multipass ntt65536: {rps:.1} rps, {stage_jobs} stage jobs --");
    (rps, stage_jobs)
}

struct Row {
    config: String,
    ntt_rps: f64,
    completed: u64,
    shed: u64,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let duration = if quick { Duration::from_millis(1500) } else { Duration::from_secs(4) };
    let mp_count = if quick { 3 } else { 10 };
    println!(
        "\n=== ntt: Goldilocks serving throughput{} ===",
        if quick { " (quick mode)" } else { "" }
    );

    let mut rows = Vec::new();
    for points in [1024usize, 4096] {
        let (rps, completed, shed) = run_saturated(points, duration);
        let config = format!("saturated_2shard_{points}");
        rows.push(Row { config, ntt_rps: rps, completed, shed });
    }
    let (mp_rps, stage_jobs) = run_multipass(mp_count);
    rows.push(Row {
        config: "multipass_65536".into(),
        ntt_rps: mp_rps,
        completed: mp_count as u64,
        shed: 0,
    });
    assert_eq!(stage_jobs, 512 * mp_count as u64, "every request decomposes 256 + 256");

    println!("\n  {:<24} {:>12} {:>10} {:>10}", "config", "ntt_rps", "completed", "shed");
    for r in &rows {
        println!("  {:<24} {:>12.1} {:>10} {:>10}", r.config, r.ntt_rps, r.completed, r.shed);
    }

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let _ = write!(
                json,
                "  {{\"bench\": \"ntt\", \"config\": \"{}\", \"ntt_rps\": {:.1}, \
                 \"completed\": {}, \"shed\": {}, \"quick\": {}}}{}\n",
                r.config,
                r.ntt_rps,
                r.completed,
                r.shed,
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
