//! Bench: N-class QoS under overload — weight-share conformance,
//! per-class tail latency, and the degrade-vs-scale crossover.
//!
//! Two scenarios, both calibrated against this host's measured
//! single-shard capacity so the overload means the same thing on fast
//! and slow runners:
//!
//! * **share**: three weighted classes (gold 5 / silver 3 / bronze 1)
//!   offered equal thirds of a saturating load through a fixed
//!   two-shard pool. Reports each class's achieved throughput, its
//!   served share vs the weight share (`share_err` — the WFQ
//!   conformance number the CI gate ceilings), and the per-class
//!   queue-wait p99.
//! * **crossover**: a one-shard pool behind a degrade-armed controller.
//!   A short burst must be absorbed by the resolution ladder (degrade
//!   events, zero shard adds — the scale-up cooldown outlasts the
//!   burst), and a sustained overload must spend the ladder, add
//!   shards, and end restored to full resolution. Violations panic, so
//!   the crossover is hard-gated by the bench run itself; the share
//!   metrics ride in the JSON rows for the numeric gate.
//!
//! ```sh
//! cargo bench --bench qos                      # full sweep
//! cargo bench --bench qos -- --quick           # CI-sized sweep
//! cargo bench --bench qos -- --json BENCH_qos.json
//! ```

use std::fmt::Write as _;
use std::time::{Duration, Instant};

use egpu_fft::coordinator::{
    default_two_class, loadgen, AdmissionPolicy, AutoscaleController, AutoscaleLog,
    AutoscalePolicy, Backend, DegradeLevel, LoadReport, LoadgenConfig, QosClass, ServerConfig,
    ServiceConfig, ServiceHandle, ShardPoolConfig, ShardedFftService, TrafficServer,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn sharded(shards: usize) -> ShardedFftService {
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    svc.run_batch((0..8).map(|i| signal(1024, i)).collect()).unwrap(); // warm
    svc
}

/// Measured single-shard fft1024 serving capacity on this host, jobs/s
/// (shared library helper — same anchor as the autoscale bench/tests).
fn calibrate_single_shard_rps() -> f64 {
    ShardedFftService::calibrate_single_shard_rps(1024).unwrap()
}

struct Row {
    config: String,
    class: String,
    weight: u32,
    achieved_rps: f64,
    share_err: f64,
    served_fraction: f64,
    weight_fraction: f64,
    queue_p99_ms: f64,
}

/// Saturate a fixed two-shard pool with an equal-thirds mix over three
/// weighted classes; one row per class.
fn run_share(base_rps: f64, duration: Duration) -> Vec<Row> {
    let weights = [("gold", 5u32), ("silver", 3), ("bronze", 1)];
    let server = TrafficServer::start(
        ServiceHandle::Sharded(sharded(2)),
        ServerConfig {
            classes: weights.iter().map(|&(n, w)| QosClass::new(n, w).with_capacity(32)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: 6.0 * base_rps, // ~3x the two-shard pool: saturated
            duration,
            sizes: vec![1024],
            class_mix: vec![1.0, 1.0, 1.0],
            deadline: None,
            ..Default::default()
        },
    );
    assert!(report.accounted, "share scenario must account every request");
    assert!(report.shed > 0, "share scenario must saturate (no shed observed)");
    let elapsed = report.elapsed_s.max(1e-9);
    let total_completed: u64 = report.per_class.iter().map(|c| c.completed).sum();
    let total_w: u32 = weights.iter().map(|&(_, w)| w).sum();
    let rows = report
        .per_class
        .iter()
        .map(|c| {
            let weight_fraction = c.weight as f64 / total_w as f64;
            let served_fraction = if total_completed == 0 {
                0.0
            } else {
                c.completed as f64 / total_completed as f64
            };
            Row {
                config: "share_3class".into(),
                class: c.name.clone(),
                weight: c.weight,
                achieved_rps: c.completed as f64 / elapsed,
                share_err: (served_fraction - weight_fraction).abs(),
                served_fraction,
                weight_fraction,
                queue_p99_ms: c.queue_p99_us / 1e3,
            }
        })
        .collect();
    print!("{}", report.render());
    server.shutdown();
    rows
}

/// One crossover phase: a fresh one-shard pool behind a degrade-armed
/// controller, one open-loop overload, then an idle drain until the
/// operating level is back at `Full`. Only the offered-rate factor,
/// the duration and the scale-up cooldown differ between the two
/// phases — everything else is shared here so they stay comparable.
/// Returns `(load report, controller log, final shard count)`.
fn crossover_phase(
    label: &str,
    rate_factor: f64,
    duration: Duration,
    scale_up_cooldown: Duration,
    base_rps: f64,
) -> (LoadReport, AutoscaleLog, usize) {
    let server = TrafficServer::start(
        ServiceHandle::Sharded(sharded(1)),
        ServerConfig {
            classes: default_two_class().into_iter().map(|c| c.with_capacity(128)).collect(),
            policy: AdmissionPolicy::Shed,
            dispatchers: 8,
            ..Default::default()
        },
    )
    .unwrap();
    let control = server.degrade_control();
    let controller = AutoscaleController::spawn(
        &server,
        AutoscalePolicy {
            min_shards: 1,
            max_shards: 4,
            target_p99_ms: 10.0,
            max_shed_rate: 0.02,
            max_degrade: DegradeLevel::Quarter,
            degrade_cooldown: Duration::from_millis(50),
            restore_cooldown: Duration::from_millis(100),
            scale_up_cooldown,
            scale_down_cooldown: Duration::from_secs(120),
            interval: Duration::from_millis(25),
            ..Default::default()
        },
    )
    .unwrap();
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: rate_factor * base_rps,
            duration,
            sizes: vec![1024],
            deadline: None,
            ..Default::default()
        },
    );
    // idle-drain until resolution is restored
    let deadline = Instant::now() + Duration::from_secs(5);
    while control.get() != DegradeLevel::Full && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
    }
    let restored = control.get() == DegradeLevel::Full;
    let log = controller.stop();
    let shards = server.service().as_sharded().unwrap().shards();
    println!("-- crossover {label} --");
    print!("{}", log.render());
    assert!(report.accounted, "{label} phase must account every request");
    assert!(restored, "{label}: resolution restored once the load cleared");
    server.shutdown();
    (report, log, shards)
}

fn crossover_row(config: &str, report: &LoadReport) -> Row {
    Row {
        config: config.into(),
        class: "all".into(),
        weight: 1,
        achieved_rps: report.achieved_rps,
        share_err: 0.0,
        served_fraction: 1.0,
        weight_fraction: 1.0,
        queue_p99_ms: report.queue_wait_us[2] / 1e3,
    }
}

/// The degrade-vs-scale crossover on a one-shard pool. Returns a burst
/// row and a sustained row; panics (failing the bench job) when either
/// side of the crossover does not happen.
fn run_crossover(base_rps: f64, burst: Duration, sustained: Duration) -> Vec<Row> {
    // burst at 3x one shard: the 60s scale-up cooldown outlasts the
    // burst, so the ladder is the only admissible lever
    let (report, log, shards) =
        crossover_phase("burst", 3.0, burst, Duration::from_secs(60), base_rps);
    assert!(
        log.degrades() >= 1,
        "burst must be served down the ladder (no degrade event):\n{}",
        log.render()
    );
    assert_eq!(log.scale_ups(), 0, "a short burst must not add a shard:\n{}", log.render());
    assert_eq!(shards, 1, "burst left the pool at one shard");
    let burst_row = crossover_row("crossover_burst", &report);

    // sustained at 6x one shard: beyond the whole ladder budget
    // (Quarter ≈ 4x), so degradation alone cannot absorb it, capacity
    // must be added, and the run ends scaled up at full resolution
    let (report, log, shards) =
        crossover_phase("sustained", 6.0, sustained, Duration::from_millis(250), base_rps);
    assert!(log.scale_ups() >= 1, "sustained overload must add capacity:\n{}", log.render());
    assert!(shards > 1, "sustained overload ends with a wider pool");
    vec![burst_row, crossover_row("crossover_sustained", &report)]
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let (share_dur, burst_dur, sustained_dur) = if quick {
        (
            Duration::from_millis(1200),
            Duration::from_millis(700),
            Duration::from_millis(1800),
        )
    } else {
        (
            Duration::from_secs(4),
            Duration::from_millis(900),
            Duration::from_secs(4),
        )
    };
    let base_rps = calibrate_single_shard_rps();
    println!(
        "\n=== qos: 3-class WFQ shares + degrade-vs-scale crossover \
         (single-shard capacity ~{base_rps:.0} rps{}) ===",
        if quick { ", quick mode" } else { "" }
    );

    let mut rows = run_share(base_rps, share_dur);
    rows.extend(run_crossover(base_rps, burst_dur, sustained_dur));

    println!(
        "\n  {:<20} {:<8} {:>12} {:>10} {:>12}",
        "config", "class", "rps", "share_err", "queue_p99_ms"
    );
    for r in &rows {
        println!(
            "  {:<20} {:<8} {:>12.0} {:>10.3} {:>12.1}",
            r.config, r.class, r.achieved_rps, r.share_err, r.queue_p99_ms
        );
    }

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let _ = write!(
                json,
                "  {{\"bench\": \"qos\", \"config\": \"{}\", \"class\": \"{}\", \
                 \"weight\": {}, \"achieved_rps\": {:.1}, \"share_err\": {:.4}, \
                 \"served_fraction\": {:.4}, \"weight_fraction\": {:.4}, \
                 \"queue_p99_ms\": {:.1}, \"quick\": {}}}{}\n",
                r.config,
                r.class,
                r.weight,
                r.achieved_rps,
                r.share_err,
                r.served_fraction,
                r.weight_fraction,
                r.queue_p99_ms,
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
