//! Bench: simulator hot-path performance and design ablations —
//! (a) raw simulation throughput (the §Perf L3 target),
//! (b) the list-scheduler ablation (NOP cycles with/without),
//! (c) the VM / complex-FU feature ablations (the paper's §6 deltas),
//! (d) codegen + assembler round-trip cost.
//!
//! `cargo bench --bench simulator`

mod harness;

use egpu_fft::arch::{SmConfig, Variant};
use egpu_fft::fft::{self, generate_opt, reference};
use egpu_fft::isa::OpClass;

fn main() {
    harness::section("simulation throughput (4096-pt radix-16, DP)");
    let cfg = SmConfig::for_radix(Variant::DP, 16);
    let fp = fft::generate(&cfg, 4096, 16).unwrap();
    let input: Vec<(f32, f32)> = reference::test_signal(4096, 3)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect();
    let mut cycles = 0u64;
    let r = harness::bench("simulate_fft4096_radix16", 2000, || {
        let run = fft::run_fft(&fp, &cfg, &input).unwrap();
        cycles = run.profile.total();
    });
    let cps = cycles as f64 / r.mean.as_secs_f64();
    println!(
        "  {cycles} simulated cycles per run -> {:.1} M simulated cycles/s\n\
         (simulated hardware runs {cycles} cycles in {:.1} us at 771 MHz;\n\
          slowdown factor {:.0}x)",
        cps / 1e6,
        cycles as f64 / 771.0,
        r.mean.as_secs_f64() / (cycles as f64 / 771e6)
    );

    harness::section("scheduler ablation (hazard NOPs at shallow wavefronts)");
    for (points, radix) in [(256usize, 4usize), (256, 16), (512, 8)] {
        let cfg = SmConfig::for_radix(Variant::DP, radix);
        let sig: Vec<(f32, f32)> = reference::test_signal(points, 1)
            .iter()
            .map(|c| c.to_f32_pair())
            .collect();
        let mut nops = [0u64; 2];
        for (i, sched) in [false, true].into_iter().enumerate() {
            let fp = generate_opt(&cfg, points, radix, sched).unwrap();
            let run = fft::run_fft(&fp, &cfg, &sig).unwrap();
            nops[i] = run.profile.get(OpClass::Nop);
        }
        println!(
            "  {points}-pt radix-{radix}: NOP cycles {} unscheduled -> {} scheduled ({:.0}% removed)",
            nops[0],
            nops[1],
            100.0 * (nops[0] - nops[1]) as f64 / nops[0].max(1) as f64
        );
    }

    harness::section("feature ablations (4096-pt radix-16 totals)");
    let base = run_total(4096, 16, Variant::DP);
    for v in [
        Variant::DP_VM,
        Variant::DP_COMPLEX,
        Variant::DP_VM_COMPLEX,
        Variant::QP,
        Variant::QP_COMPLEX,
    ] {
        let t = run_total(4096, 16, v);
        println!(
            "  {:<18} total {:>6} cycles ({:+.1}% vs DP), time {:>6.2} us, eff {:>5.2}%",
            v.name(),
            t.0,
            100.0 * (t.0 as f64 - base.0 as f64) / base.0 as f64,
            t.1,
            t.2
        );
    }

    harness::section("multi-batch amortization (§6: 'amortized away for multi-batch FFTs')");
    for (points, radix, batch) in [(1024usize, 4usize, 4usize), (512, 8, 4), (256, 4, 8)] {
        let cfg = SmConfig::for_radix(Variant::DP, radix);
        let single = run_total(points, radix, Variant::DP);
        let fp = egpu_fft::fft::generate_batched(&cfg, points, radix, batch).unwrap();
        let inputs: Vec<Vec<(f32, f32)>> = (0..batch)
            .map(|b| {
                reference::test_signal(points, b as u64)
                    .iter()
                    .map(|c| c.to_f32_pair())
                    .collect()
            })
            .collect();
        let (_, prof) = egpu_fft::fft::run_fft_batch(&fp, &cfg, &inputs).unwrap();
        let per_fft = prof.total() as f64 / batch as f64;
        println!(
            "  fft{points} r{radix} x{batch}: {:.0} cycles/FFT vs {} single (-{:.1}%), eff {:.2}% vs {:.2}%",
            per_fft,
            single.0,
            100.0 * (1.0 - per_fft / single.0 as f64),
            prof.efficiency_pct(),
            single.2
        );
    }

    harness::section("reduction workload (§4: VM helps 'FFTs and reduction')");
    for v in [Variant::DP, Variant::DP_VM, Variant::QP] {
        let cfg = SmConfig::for_radix(v, 4);
        let rp = egpu_fft::apps::reduction::generate(&cfg, 8192).unwrap();
        let input: Vec<f32> = reference::test_signal(8192, 9)
            .iter()
            .map(|c| c.re as f32)
            .collect();
        let (_, prof) = egpu_fft::apps::reduction::run(&rp, &cfg, &input).unwrap();
        println!(
            "  reduce8192 on {:<18} total {:>5} cycles, {:.2} us",
            v.name(),
            prof.total(),
            prof.time_us()
        );
    }

    harness::section("codegen + scheduling cost");
    for (points, radix) in [(4096usize, 4usize), (4096, 8), (4096, 16), (1024, 16)] {
        let cfg = SmConfig::for_radix(Variant::DP_VM_COMPLEX, radix);
        harness::bench(&format!("generate_fft{points}_r{radix}"), 400, || {
            let _ = fft::generate(&cfg, points, radix).unwrap();
        });
    }

    harness::section("assembler round-trip");
    let cfg = SmConfig::for_radix(Variant::DP, 16);
    let listing: String = fft::generate(&cfg, 4096, 16)
        .unwrap()
        .program
        .insts
        .iter()
        .map(|i| format!("{i}\n"))
        .collect();
    harness::bench("assemble_fft4096_listing", 400, || {
        let _ = egpu_fft::isa::asm::assemble("rt", &listing).unwrap();
    });
}

fn run_total(points: usize, radix: usize, v: Variant) -> (u64, f64, f64) {
    let cfg = SmConfig::for_radix(v, radix);
    let (p, err) = fft::validate(&cfg, points, radix, 7).unwrap();
    assert!(err < fft::F32_TOL);
    (p.total(), p.time_us(), p.efficiency_pct())
}
