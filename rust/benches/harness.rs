//! Minimal criterion-style benchmark harness (criterion itself is not
//! in the offline vendor tree). Adaptive iteration count, warmup,
//! mean ± stddev reporting.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub stddev: Duration,
    pub iters: u32,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} {:>12.3?} ± {:>10.3?}  ({} iters)",
            self.name, self.mean, self.stddev, self.iters
        );
    }
}

/// Run `f` with warmup until ~`target_ms` of samples are collected.
pub fn bench<F: FnMut()>(name: &str, target_ms: u64, mut f: F) -> BenchResult {
    // warmup
    let warm_start = Instant::now();
    f();
    let first = warm_start.elapsed();
    // choose iteration count for the target
    let iters = ((target_ms as f64 * 1e-3) / first.as_secs_f64().max(1e-9))
        .clamp(1.0, 10_000.0) as u32;
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
        / samples.len().max(1) as f64;
    let r = BenchResult {
        name: name.to_string(),
        mean: Duration::from_secs_f64(mean),
        stddev: Duration::from_secs_f64(var.sqrt()),
        iters,
    };
    r.print();
    r
}

/// Pretty section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
