//! Bench: sharded-scheduler throughput scaling, 1/2/4/8 shards across
//! FFT sizes 256–4096.
//!
//! Each configuration serves a homogeneous batch through
//! `ShardedFftService::request_all` with the steal threshold at 0
//! (steal on any backlog), so the batch chunks across every shard. The
//! simulated SM work dominates the dispatch cost, so throughput should
//! scale near-linearly with the shard count up to the host's core
//! count — the acceptance bar is ≥ 3× aggregate throughput at 4 shards
//! vs 1 shard on 1024-point batches. Outputs are additionally checked
//! bitwise against the single-shard results on every size.
//!
//! ```sh
//! cargo bench --bench shard                       # full sweep
//! cargo bench --bench shard -- --quick            # CI-sized sweep
//! cargo bench --bench shard -- --json BENCH_shard.json
//! ```

mod harness;

use std::fmt::Write as _;

use egpu_fft::coordinator::{
    Backend, FftRequest, ServiceConfig, ShardPoolConfig, ShardedFftService,
};
use egpu_fft::fft::reference;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn bits(v: &[(f32, f32)]) -> Vec<(u32, u32)> {
    v.iter().map(|&(r, i)| (r.to_bits(), i.to_bits())).collect()
}

fn service(shards: usize, jobs: usize) -> ShardedFftService {
    ShardedFftService::start(ShardPoolConfig {
        shards,
        steal_threshold: 0,
        // chunk the batch all the way down to one chunk per shard
        min_chunk: (jobs / 8).max(1),
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap()
}

struct Row {
    points: usize,
    shards: usize,
    jobs_per_s: f64,
    speedup: f64,
    steals: u64,
    hit_rate: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let (sizes, shard_counts, jobs, target_ms): (&[usize], &[usize], usize, u64) = if quick {
        (&[256, 1024], &[1, 2, 4], 16, 200)
    } else {
        (&[256, 512, 1024, 2048, 4096], &[1, 2, 4, 8], 64, 1000)
    };

    harness::section(&format!(
        "sharded scaling: {jobs} same-size jobs per batch, steal threshold 0{}",
        if quick { " (quick mode)" } else { "" }
    ));

    let mut rows: Vec<Row> = Vec::new();
    for &points in sizes {
        let inputs: Vec<Vec<(f32, f32)>> =
            (0..jobs).map(|i| signal(points, i as u64)).collect();

        // single-shard reference outputs: the bitwise baseline
        let reference_bits: Vec<Vec<(u32, u32)>> = {
            let svc = service(1, jobs);
            let results = svc.request_all(inputs.clone().into_iter().map(FftRequest::new).collect()).unwrap();
            let b = results.iter().map(|r| bits(&r.output)).collect();
            svc.shutdown();
            b
        };

        let mut base_jps = 0.0;
        for &shards in shard_counts {
            let svc = service(shards, jobs);
            // warm the shared plan cache and every shard's executor
            let warm = svc.request_all(inputs.clone().into_iter().map(FftRequest::new).collect()).unwrap();
            for (r, want) in warm.iter().zip(&reference_bits) {
                assert_eq!(
                    bits(&r.output),
                    *want,
                    "sharded output diverged from single-shard at fft{points}"
                );
            }
            let res = harness::bench(
                &format!("submit_batch_{jobs}x_fft{points}_{shards}shard"),
                target_ms,
                || {
                    svc.request_all(inputs.clone().into_iter().map(FftRequest::new).collect()).unwrap();
                },
            );
            let jps = jobs as f64 / res.mean.as_secs_f64();
            if shards == 1 {
                base_jps = jps;
            }
            let m = svc.metrics();
            rows.push(Row {
                points,
                shards,
                jobs_per_s: jps,
                speedup: jps / base_jps,
                steals: m.steals,
                hit_rate: m.plan_cache.hit_rate(),
            });
            svc.shutdown();
        }

        let per_size: Vec<&Row> = rows.iter().filter(|r| r.points == points).collect();
        let line = per_size
            .iter()
            .map(|r| format!("{}sh {:.0} j/s ({:.2}x)", r.shards, r.jobs_per_s, r.speedup))
            .collect::<Vec<_>>()
            .join(", ");
        println!("  fft{points}: {line}");
    }

    let at = |points: usize, shards: usize| {
        rows.iter()
            .find(|r| r.points == points && r.shards == shards)
            .map(|r| r.speedup)
    };
    if let Some(s4) = at(1024, 4) {
        println!(
            "\n4-shard speedup on fft1024 batches: {s4:.2}x (acceptance bar: >= 3x on a \
             >= 4-core host)"
        );
    }

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let _ = write!(
                json,
                "  {{\"bench\": \"shard\", \"points\": {}, \"shards\": {}, \
                 \"jobs_per_s\": {:.1}, \"speedup_vs_1_shard\": {:.3}, \"steals\": {}, \
                 \"plan_cache_hit_rate\": {:.4}, \"quick\": {}}}{}\n",
                r.points,
                r.shards,
                r.jobs_per_s,
                r.speedup,
                r.steals,
                r.hit_rate,
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
