//! Bench: the cross-platform comparisons — Table 5 (FFT IP core),
//! Table 6 (A100/V100 cuFFT), Figures 2 & 4, plus the IP-vs-eGPU
//! throughput crossover series the paper's §7 discussion implies.
//!
//! `cargo bench --bench comparisons`

mod harness;

use egpu_fft::fft::{reference, twiddle::Cpx};
use egpu_fft::ipcore::{IpCore, StreamingSdf};
use egpu_fft::report;

fn main() {
    harness::section("Table 5: eGPU vs streaming FFT IP core");
    let mut rows = None;
    harness::bench("table5_ip_comparison", 1000, || {
        rows = Some(report::table5().unwrap());
    });
    let rows = rows.unwrap();
    println!("\n{}", report::render_table5(&rows));
    println!("paper: perf ratio ~5-7x, normalized ~2.6-3.5x (\"only about a 3x advantage\")");
    for r in &rows {
        println!(
            "  {}: perf {:.1}x, normalized {:.1}x",
            r.points, r.perf_ratio, r.normalized_ratio
        );
    }

    harness::section("Table 6: FFT efficiency vs A100/V100");
    let mut t6 = None;
    harness::bench("table6_gpu_comparison", 1000, || {
        t6 = Some(report::table6().unwrap());
    });
    println!("\n{}", report::render_table6(&t6.unwrap()));

    harness::section("Figure 2: per-pass index map");
    harness::bench("figure2_index_map", 100, || {
        let _ = report::figure2(32, 3).unwrap();
    });
    println!("\n{}", report::figure2(8, 3).unwrap());

    harness::section("Figure 4: floorplan footprint");
    harness::bench("figure4_floorplan", 100, || {
        let _ = report::figure4();
    });
    println!("\n{}", report::figure4());

    harness::section("behavioural streaming IP (R2SDF) throughput check");
    for n in [256usize, 1024, 4096] {
        let sig = reference::test_signal(n, 5);
        let mut cycles = 0usize;
        harness::bench(&format!("sdf_stream_fft{n}"), 300, || {
            let mut sdf = StreamingSdf::new(n);
            let frames: Vec<&[Cpx]> = vec![&sig, &sig, &sig, &sig];
            let out = sdf.transform_frames(&frames);
            assert_eq!(out.len(), 4);
            cycles = n; // steady-state cycles per frame by construction
        });
        let ip = IpCore::paper(n).unwrap();
        println!(
            "  fft{n}: modelled {:.2} us/frame at {:.0} MHz streaming (paper Table 5: {:.2} us)",
            n as f64 / (n as f64 / ip.time_us),
            n as f64 / ip.time_us,
            ip.time_us
        );
    }
}
