//! Bench: multi-pass large-N FFT throughput past the 4096-point
//! single-pass ceiling, by size and serving strategy.
//!
//! Each request is one four-step decomposition served through the
//! unified `FftRequest` API on a 4-shard pool. Two strategies per size:
//!
//! * **pipelined** — the reservation path: each stage arrives as one
//!   coalesced `request_all` batch, chunked across every shard, so the
//!   row and column passes use the whole pool.
//! * **serialized** — the spill path (zero reservation permits): every
//!   sub-job is a separate `request` round trip, one at a time — the
//!   degraded mode a saturated gate falls back to, and the bound the
//!   pipelined path must beat.
//!
//! `mp_rps` (multi-pass requests per second) is the gated metric; the
//! run also hard-asserts that the pipelined strategy spreads stage
//! batches across shards and comes out ahead of serialize-passes.
//!
//! ```sh
//! cargo bench --bench largefft                  # full sweep (adds 2^20)
//! cargo bench --bench largefft -- --quick       # CI-sized sweep
//! cargo bench --bench largefft -- --json BENCH_largefft.json
//! ```

mod harness;

use std::fmt::Write as _;

use egpu_fft::coordinator::{
    Backend, FftRequest, ServiceConfig, ShardPoolConfig, ShardedFftService,
};
use egpu_fft::fft::reference;

const SHARDS: usize = 4;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

/// A 4-shard pool; `serialize` forces the spill path by granting zero
/// multi-pass reservation permits.
fn service(serialize: bool) -> ShardedFftService {
    ShardedFftService::start(ShardPoolConfig {
        shards: SHARDS,
        steal_threshold: 0,
        service: ServiceConfig {
            backend: Backend::Simulator,
            max_inflight_multipass: if serialize { 0 } else { 2 },
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap()
}

struct Row {
    points: usize,
    mode: &'static str,
    mp_rps: f64,
    stage_jobs: u64,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let (sizes, target_ms): (&[usize], u64) = if quick {
        (&[1 << 13, 1 << 16], 200)
    } else {
        (&[1 << 13, 1 << 16, 1 << 20], 1000)
    };

    harness::section(&format!(
        "multi-pass large-N FFT: four-step requests on {SHARDS} shards, pipelined vs \
         serialize-passes{}",
        if quick { " (quick mode)" } else { "" }
    ));

    let mut rows: Vec<Row> = Vec::new();
    for &points in sizes {
        let input = signal(points, 11);
        let mut pipelined_rps = 0.0;
        for (mode, serialize) in [("pipelined", false), ("serialized", true)] {
            let svc = service(serialize);
            // warm the plan/twiddle caches and every shard's executor
            svc.request(FftRequest::new(input.clone())).recv().unwrap().unwrap();
            let res = harness::bench(
                &format!("multipass_fft{points}_{mode}"),
                target_ms,
                || {
                    svc.request(FftRequest::new(input.clone()))
                        .recv()
                        .unwrap()
                        .unwrap();
                },
            );
            let rps = 1.0 / res.mean.as_secs_f64();
            let m = svc.metrics();
            // per-request sub-job count (the counters accumulate over
            // the warmup and every timed iteration)
            let stage_jobs = m.multipass.stage_jobs() / m.multipass.requests.max(1);
            if serialize {
                assert!(
                    m.multipass.spilled == m.multipass.requests,
                    "zero permits must spill every request: {:?}",
                    m.multipass
                );
            } else {
                pipelined_rps = rps;
                assert!(
                    m.multipass.reserved == m.multipass.requests,
                    "an idle gate must reserve every request: {:?}",
                    m.multipass
                );
                let serving = m.shards.iter().filter(|s| s.handled > 0).count();
                assert!(
                    serving >= 2,
                    "pipelined stage batches must chunk across shards: {:?}",
                    m.shards
                );
            }
            rows.push(Row { points, mode, mp_rps: rps, stage_jobs });
            svc.shutdown();
        }
        let serialized_rps = rows.last().map(|r| r.mp_rps).unwrap_or(0.0);
        println!(
            "  fft{points}: pipelined {pipelined_rps:.2} req/s vs serialized \
             {serialized_rps:.2} req/s ({:.2}x)",
            pipelined_rps / serialized_rps
        );
        assert!(
            pipelined_rps > serialized_rps,
            "pipelining stage batches across shards must beat per-sub-job round trips \
             at fft{points}"
        );
    }

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let _ = write!(
                json,
                "  {{\"bench\": \"largefft\", \"points\": {}, \"mode\": \"{}\", \
                 \"mp_rps\": {:.4}, \"stage_jobs\": {}, \"quick\": {}}}{}\n",
                r.points,
                r.mode,
                r.mp_rps,
                r.stage_jobs,
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
