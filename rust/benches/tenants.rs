//! Bench: multi-tenant isolation under an adversarial flood — the
//! hard interference guarantee the CI gate enforces.
//!
//! Two phases on identical fresh two-shard servers, calibrated against
//! this host's measured single-shard capacity so the flood means the
//! same thing on fast and slow runners:
//!
//! * **solo**: the well-behaved victim tenant alone, offered well under
//!   its token-bucket rate. Its queue-wait p99 is the interference
//!   baseline.
//! * **adversarial**: the same victim traffic, plus an abusive tenant
//!   offered 10x its own bucket rate. The bucket must cap the abuser's
//!   *admitted* rate at its contract, so the class queues never see the
//!   flood and the victim's p99 barely moves.
//!
//! The headline number is `p99_interference` — the victim's
//! adversarial-phase queue-wait p99 over its solo p99 (floored at 1ms:
//! the log2-bucket recorder quantizes within 2x, so sub-millisecond
//! baselines would turn quantization noise into ratio noise). The run
//! itself hard-asserts the isolation contract: the ratio stays bounded,
//! the victim is never throttled, the abuser is throttled heavily, and
//! the abuser's admitted count respects rate x window + burst.
//!
//! ```sh
//! cargo bench --bench tenants                      # full run
//! cargo bench --bench tenants -- --quick           # CI-sized run
//! cargo bench --bench tenants -- --json BENCH_tenants.json
//! ```

use std::fmt::Write as _;
use std::time::Duration;

use egpu_fft::coordinator::{
    loadgen, AdmissionPolicy, Backend, LoadReport, LoadgenConfig, ServerConfig, ServiceConfig,
    ServiceHandle, ShardPoolConfig, ShardedFftService, TenantSpec, TrafficServer,
};
use egpu_fft::fft::reference;

/// The hard ceiling on the victim-p99 interference ratio, mirrored by
/// the `tenants.p99_interference_max` gate in `BENCH_baseline.json`.
const MAX_INTERFERENCE: f64 = 8.0;

/// Floor for the solo p99 when forming the ratio, µs (defends the
/// ratio against the recorder's 2x log2-bucket quantization).
const SOLO_P99_FLOOR_US: f64 = 1000.0;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn sharded(shards: usize) -> ShardedFftService {
    let svc = ShardedFftService::start(ShardPoolConfig {
        shards,
        steal_threshold: 0,
        service: ServiceConfig { backend: Backend::Simulator, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    svc.run_batch((0..8).map(|i| signal(1024, i)).collect()).unwrap(); // warm
    svc
}

/// Measured single-shard fft1024 serving capacity on this host, jobs/s
/// (shared library helper — same anchor as the qos/autoscale benches).
fn calibrate_single_shard_rps() -> f64 {
    ShardedFftService::calibrate_single_shard_rps(1024).unwrap()
}

/// The two-tenant contract both phases run under: the victim's bucket
/// has comfortable headroom over its offered rate; the abuser's bucket
/// caps it at half a shard no matter how hard it floods.
fn tenant_roster(base_rps: f64) -> Vec<TenantSpec> {
    vec![
        TenantSpec::new("victim", 0.4 * base_rps, (0.4 * base_rps).ceil() as u64)
            .with_priority(),
        TenantSpec::new("abuser", 0.5 * base_rps, (0.1 * base_rps).ceil() as u64 + 1),
    ]
}

/// One phase on a fresh two-shard server: `victim_rps` + `abuser_rps`
/// offered open-loop for `duration`, split across the two tenants.
fn run_phase(
    label: &str,
    base_rps: f64,
    victim_rps: f64,
    abuser_rps: f64,
    duration: Duration,
) -> LoadReport {
    let server = TrafficServer::start(
        ServiceHandle::Sharded(sharded(2)),
        ServerConfig {
            policy: AdmissionPolicy::Shed,
            dispatchers: 4,
            tenants: tenant_roster(base_rps),
            ..Default::default()
        },
    )
    .unwrap();
    let report = loadgen::run(
        &server,
        &LoadgenConfig {
            rate_hz: victim_rps + abuser_rps,
            duration,
            sizes: vec![1024],
            tenant_mix: vec![victim_rps, abuser_rps],
            deadline: None,
            ..Default::default()
        },
    );
    println!("-- {label} --");
    print!("{}", report.render());
    assert!(report.accounted, "{label}: every request must be answered");
    server.shutdown();
    report
}

fn tenant<'a>(report: &'a LoadReport, name: &str) -> &'a loadgen::TenantLoadRow {
    report
        .per_tenant
        .iter()
        .find(|t| t.name == name)
        .unwrap_or_else(|| panic!("tenant {name} missing from report"))
}

struct Row {
    tenant: String,
    tenant_rps: f64,
    p99_interference: f64,
    solo_p99_ms: f64,
    adv_p99_ms: f64,
    admitted: u64,
    throttled: u64,
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let duration = if quick { Duration::from_millis(1500) } else { Duration::from_secs(4) };
    let base_rps = calibrate_single_shard_rps();
    let victim_rps = 0.25 * base_rps;
    let abuser_limit = 0.5 * base_rps;
    let abuser_rps = 10.0 * abuser_limit; // 10x its own bucket rate
    println!(
        "\n=== tenants: adversarial isolation (single-shard capacity ~{base_rps:.0} rps, \
         victim {victim_rps:.0} rps, abuser {abuser_rps:.0} rps offered vs \
         {abuser_limit:.0} rps contract{}) ===",
        if quick { ", quick mode" } else { "" }
    );

    let solo = run_phase("solo victim", base_rps, victim_rps, 0.0, duration);
    let adv = run_phase("adversarial flood", base_rps, victim_rps, abuser_rps, duration);

    let solo_victim = tenant(&solo, "victim");
    let adv_victim = tenant(&adv, "victim");
    let adv_abuser = tenant(&adv, "abuser");

    let solo_p99 = solo_victim.queue_p99_us.max(SOLO_P99_FLOOR_US);
    let interference = adv_victim.queue_p99_us / solo_p99;

    // The isolation contract, hard-asserted so the bench run itself
    // fails CI when any leg breaks — the numeric gate only ratchets.
    assert_eq!(
        adv_victim.throttled, 0,
        "victim under its contract must never be throttled"
    );
    assert!(
        adv_abuser.throttled > 0,
        "a 10x flood must hit the abuser's token bucket"
    );
    let window = adv.elapsed_s;
    let bucket_cap = abuser_limit * window + tenant_roster(base_rps)[1].burst as f64;
    assert!(
        (adv_abuser.admitted as f64) <= bucket_cap,
        "abuser admitted {} beyond its bucket contract ({:.0} over {:.2}s)",
        adv_abuser.admitted,
        bucket_cap,
        window
    );
    assert!(
        interference <= MAX_INTERFERENCE,
        "abusive tenant moved the victim's p99 {interference:.2}x \
         (solo {:.0}us -> adversarial {:.0}us, cap {MAX_INTERFERENCE}x)",
        solo_p99,
        adv_victim.queue_p99_us
    );

    let rows = [
        Row {
            tenant: "victim".into(),
            tenant_rps: adv_victim.achieved_rps,
            p99_interference: interference,
            solo_p99_ms: solo_p99 / 1e3,
            adv_p99_ms: adv_victim.queue_p99_us / 1e3,
            admitted: adv_victim.admitted,
            throttled: adv_victim.throttled,
        },
        Row {
            tenant: "abuser".into(),
            tenant_rps: adv_abuser.achieved_rps,
            // interference is a victim-side metric; the abuser's row
            // carries 0.0 so the gate's max() reads only the victim
            p99_interference: 0.0,
            solo_p99_ms: 0.0,
            adv_p99_ms: adv_abuser.queue_p99_us / 1e3,
            admitted: adv_abuser.admitted,
            throttled: adv_abuser.throttled,
        },
    ];

    println!(
        "\n  {:<8} {:>12} {:>18} {:>12} {:>12} {:>10} {:>10}",
        "tenant", "rps", "p99_interference", "solo_p99_ms", "adv_p99_ms", "admitted", "throttled"
    );
    for r in &rows {
        println!(
            "  {:<8} {:>12.0} {:>18.2} {:>12.1} {:>12.1} {:>10} {:>10}",
            r.tenant, r.tenant_rps, r.p99_interference, r.solo_p99_ms, r.adv_p99_ms, r.admitted,
            r.throttled
        );
    }

    if let Some(path) = json_path {
        let mut json = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            let _ = write!(
                json,
                "  {{\"bench\": \"tenants\", \"config\": \"adversarial_2shard\", \
                 \"tenant\": \"{}\", \"tenant_rps\": {:.1}, \"p99_interference\": {:.3}, \
                 \"solo_p99_ms\": {:.2}, \"adv_p99_ms\": {:.2}, \"admitted\": {}, \
                 \"throttled\": {}, \"quick\": {}}}{}\n",
                r.tenant,
                r.tenant_rps,
                r.p99_interference,
                r.solo_p99_ms,
                r.adv_p99_ms,
                r.admitted,
                r.throttled,
                quick,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        json.push_str("]\n");
        std::fs::write(&path, json).expect("writing bench JSON");
        println!("wrote {} rows to {path}", rows.len());
    }
}
