//! Bench: batched dispatch vs one-at-a-time submission through the
//! coordinator, across FFT sizes 256–4096.
//!
//! The sequential path pays a queue hop, a shared-queue lock, a reply
//! channel and two thread wake-ups per job; `request_all` rides one
//! hop per size group and serves every job from one plan-cache lookup
//! and one resident SM. Same simulated work, less dispatch overhead —
//! batched throughput must come out ahead.
//!
//! `cargo bench --bench batch`

mod harness;

use egpu_fft::coordinator::{Backend, FftRequest, FftService, ServiceConfig};
use egpu_fft::fft::reference;

const BATCH: usize = 64;

fn signal(points: usize, seed: u64) -> Vec<(f32, f32)> {
    reference::test_signal(points, seed)
        .iter()
        .map(|c| c.to_f32_pair())
        .collect()
}

fn main() {
    harness::section(&format!(
        "batched dispatch vs sequential submit ({BATCH} same-size jobs, 1 core, radix-16 VM+Complex)"
    ));
    let mut wins = 0usize;
    let mut sizes = 0usize;
    for points in [256usize, 512, 1024, 2048, 4096] {
        let svc = FftService::start(ServiceConfig {
            cores: 1,
            backend: Backend::Simulator,
            ..Default::default()
        })
        .unwrap();
        let inputs: Vec<Vec<(f32, f32)>> =
            (0..BATCH).map(|i| signal(points, i as u64)).collect();
        // warm the plan cache and the worker's resident executor
        svc.request_all(inputs.clone().into_iter().map(FftRequest::new).collect()).unwrap();

        let seq = harness::bench(&format!("sequential_submit_{BATCH}x_fft{points}"), 1200, || {
            for input in inputs.clone() {
                svc.request(FftRequest::new(input)).recv().unwrap().unwrap();
            }
        });
        let bat = harness::bench(&format!("submit_batch_{BATCH}x_fft{points}"), 1200, || {
            svc.request_all(inputs.clone().into_iter().map(FftRequest::new).collect()).unwrap();
        });

        let seq_jps = BATCH as f64 / seq.mean.as_secs_f64();
        let bat_jps = BATCH as f64 / bat.mean.as_secs_f64();
        let m = svc.metrics();
        println!(
            "  fft{points}: sequential {seq_jps:.0} jobs/s -> batched {bat_jps:.0} jobs/s \
             ({:+.1}% throughput) | plan-cache hit rate {:.3}, mean occupancy {:.1}",
            100.0 * (bat_jps / seq_jps - 1.0),
            m.plan_cache.hit_rate(),
            m.mean_batch_occupancy(),
        );
        sizes += 1;
        if bat_jps > seq_jps {
            wins += 1;
        }
        svc.shutdown();
    }
    println!("\nbatched dispatch ahead on {wins}/{sizes} sizes");
}
