#!/usr/bin/env python3
"""CI bench-regression gate, with a self-ratcheting baseline.

Reads the quick-mode JSON rows written by `benches/shard.rs`
(`jobs_per_s` per row), `benches/loadtest.rs` (`achieved_rps` per row),
`benches/autoscale.rs` (`recovered_rps` / `shed_rate_after` /
`p99_recovery_ms` per row), `benches/qos.rs` (per-class
`achieved_rps` / `share_err` rows — the WFQ share-conformance metric)
`benches/backend.rs` (per-config `routed_rps` /
`validate_overhead` rows — multi-backend routing throughput and the
cost of validation sampling), `benches/largefft.rs` (per-size,
per-strategy `mp_rps` rows — multi-pass large-N FFT requests per
second past the single-pass ceiling) and `benches/hotpath.rs`
(per-config `ns_per_job` rows — dispatch overhead per job on the
zero-copy arena path, measured with a no-op backend so FFT compute is
excluded) and `benches/tenants.rs` (per-tenant `tenant_rps` /
`p99_interference` rows — adversarial multi-tenant isolation: the
victim's queue-wait p99 under an abusive flood over its solo p99)
and `benches/ntt.rs` (per-config `ntt_rps` rows — Goldilocks NTT
serving throughput through the same stack, saturated single-pass and
four-step multipass legs), reduces each metric to an aggregate, and
fails when an aggregate crosses the committed `BENCH_baseline.json`
limit by more than the threshold.

Two check directions:

* **floor** (throughput-like, higher is better): aggregate is the
  geometric mean across rows; fails when it drops more than the
  threshold below the committed value.
* **ceiling** (latency/shed-like, lower is better): aggregate is the
  max across rows; fails when it rises more than the threshold above
  the committed value.

The baseline is a conservative envelope, not a point estimate: CI
runners are noisy, so the gate only trips on real cliffs (default
threshold: 15%).

**Ratcheting.** `--emit-ratchet PATH` writes a suggested baseline:
floors move up to 80% of the observed aggregate (never down), ceilings
tighten to 125% of the observed aggregate (never up, and never below an
absolute per-metric minimum so a lucky zero does not weld the gate
shut). CI uploads this file as the `suggested-baseline` artifact;
committing it is a human decision. When a committed floor is more than
2x stale (the observed aggregate is over twice the floor), the gate
says so on stdout and in the GitHub job summary.

**Merging.** `--merge-artifact PATH` is a standalone mode: it applies a
downloaded `suggested-baseline` artifact onto the committed baseline
and prints the ready-to-commit merged JSON (floors only ever rise,
ceilings only ever fall, `threshold`/`_comment` and unknown keys keep
the committed values). The nightly bench-full job uses it to put a
copy-pasteable baseline into the job summary.

Usage:
    bench_gate.py --baseline BENCH_baseline.json \
                  --shard BENCH_shard.json --loadtest BENCH_loadtest.json \
                  [--autoscale BENCH_autoscale.json] \
                  [--qos BENCH_qos.json] \
                  [--backend BENCH_backend.json] \
                  [--largefft BENCH_largefft.json] \
                  [--hotpath BENCH_hotpath.json] \
                  [--tenants BENCH_tenants.json] \
                  [--ntt BENCH_ntt.json] \
                  [--emit-ratchet suggested_baseline.json]
    bench_gate.py --baseline BENCH_baseline.json \
                  --merge-artifact suggested_baseline.json
"""

import argparse
import json
import math
import os
import sys

# (section, baseline key, row field, aggregate, direction)
CHECKS = [
    ("shard", "agg_jobs_per_s", "jobs_per_s", "geomean", "floor"),
    ("loadtest", "agg_achieved_rps", "achieved_rps", "geomean", "floor"),
    ("autoscale", "agg_recovered_rps", "recovered_rps", "geomean", "floor"),
    ("autoscale", "shed_rate_after_max", "shed_rate_after", "max", "ceiling"),
    ("autoscale", "p99_recovery_ms_max", "p99_recovery_ms", "max", "ceiling"),
    ("qos", "agg_qos_rps", "achieved_rps", "geomean", "floor"),
    ("qos", "share_err_max", "share_err", "max", "ceiling"),
    ("backend", "agg_routed_rps", "routed_rps", "geomean", "floor"),
    ("backend", "validate_overhead_max", "validate_overhead", "max", "ceiling"),
    ("largefft", "agg_mp_rps", "mp_rps", "geomean", "floor"),
    ("hotpath", "ns_per_job_max", "ns_per_job", "max", "ceiling"),
    ("tenants", "agg_tenant_rps", "tenant_rps", "geomean", "floor"),
    ("tenants", "p99_interference_max", "p99_interference", "max", "ceiling"),
    ("ntt", "agg_ntt_rps", "ntt_rps", "geomean", "floor"),
]

# Ratchet tuning: floors rise toward 80% of observed; ceilings tighten
# toward 125% of observed but never below an *absolute* per-metric
# minimum. The guard must be absolute, not a fraction of the committed
# value: a relative guard decays geometrically across repeated ratchet
# commits fed by lucky-zero observations, welding the gate shut.
RATCHET_FLOOR_FRACTION = 0.8
RATCHET_CEILING_FACTOR = 1.25
RATCHET_CEILING_MIN = {
    "shed_rate_after_max": 0.02,
    "p99_recovery_ms_max": 250.0,
    # WFQ conformance: a perfect-share run must not weld the gate onto
    # zero tolerance — queue-boundary effects are real.
    "share_err_max": 0.05,
    # Validation sampling re-serves sampled requests on the simulator,
    # so some throughput loss is structural; a lucky zero-overhead run
    # must not gate future runs onto it.
    "validate_overhead_max": 0.1,
    # Dispatch overhead in ns/job: even an ideal runner pays channel
    # wakeups and a payload memcpy, so the ceiling never ratchets below
    # 20µs — a suspiciously fast run must not weld the gate onto it.
    "ns_per_job_max": 20000.0,
    # Victim-p99 interference ratio: the bench floors the solo p99 at
    # 1ms against log2-bucket quantization, but scheduling jitter is
    # real — a lucky 1.0x run must not demand perfect isolation forever.
    "p99_interference_max": 3.0,
}

STALE_FACTOR = 2.0


def geomean(values):
    """Geometric mean. Any non-positive value collapses the aggregate to
    0.0: a zero-throughput row (e.g. a fully starved QoS class) is a
    catastrophic regression and must fail its floor, not be silently
    dropped from the mean."""
    vals = list(values)
    if not vals or any(v <= 0 for v in vals):
        return 0.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def load_rows(path):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list) or not rows:
        raise SystemExit(f"{path}: expected a non-empty JSON array of bench rows")
    return rows


def column(rows, path, field):
    missing = [r for r in rows if field not in r]
    if missing:
        raise SystemExit(f"{path}: {len(missing)} rows lack the `{field}` field")
    return [float(r[field]) for r in rows]


def run_gate(baseline, files):
    """Evaluate every gated metric.

    `baseline` is the parsed BENCH_baseline.json; `files` maps section
    name -> bench JSON path (value may be absent/None for sections the
    caller did not provide). Returns (results, threshold) where each
    result dict carries section/key/field/aggregate/direction/current/
    base/limit/ok/stale. Raises SystemExit on malformed input or when
    the baseline gates a section no file was given for.
    """
    threshold = float(baseline.get("threshold", 0.15))
    rows_cache = {}
    results = []
    for section, key, field, agg, direction in CHECKS:
        sec = baseline.get(section)
        if not isinstance(sec, dict) or key not in sec:
            continue
        path = files.get(section)
        if not path:
            raise SystemExit(
                f"baseline gates `{section}.{key}` but no --{section} file was given"
            )
        if path not in rows_cache:
            rows_cache[path] = load_rows(path)
        vals = column(rows_cache[path], path, field)
        cur = geomean(vals) if agg == "geomean" else max(vals)
        base = float(sec[key])
        if direction == "floor":
            limit = base * (1.0 - threshold)
            ok = cur >= limit
            stale = base > 0 and cur > STALE_FACTOR * base
        else:
            limit = base * (1.0 + threshold)
            ok = cur <= limit
            # A ceiling already at its absolute ratchet guard cannot be
            # tightened further, so a tiny healthy observation must not
            # flag it stale forever (permanent warnings train people to
            # ignore the staleness signal entirely).
            guard = RATCHET_CEILING_MIN.get(key, 0.0)
            stale = base > STALE_FACTOR * cur + 1e-12 and base > guard + 1e-12
        results.append(
            {
                "section": section,
                "key": key,
                "field": field,
                "aggregate": agg,
                "direction": direction,
                "rows": len(vals),
                "current": cur,
                "base": base,
                "limit": limit,
                "ok": ok,
                "stale": stale,
            }
        )
    return results, threshold


def suggest(result):
    """The ratcheted baseline value for one check result."""
    cur, base = result["current"], result["base"]
    if result["direction"] == "floor":
        return max(base, RATCHET_FLOOR_FRACTION * cur)
    guard = RATCHET_CEILING_MIN.get(result["key"], 0.0)
    return min(base, max(RATCHET_CEILING_FACTOR * cur, guard))


def ratchet_baseline(baseline, results):
    """A copy of `baseline` with every gated value ratcheted."""
    out = json.loads(json.dumps(baseline))
    for r in results:
        out[r["section"]][r["key"]] = round(suggest(r), 4)
    out["_comment"] = (
        "Suggested baseline emitted by bench_gate.py --emit-ratchet: floors at "
        f"{RATCHET_FLOOR_FRACTION:.0%} of the observed aggregate (never lowered), "
        f"ceilings at {RATCHET_CEILING_FACTOR:.0%} of the observed aggregate "
        "(never raised). Review against a few runs before committing as "
        "BENCH_baseline.json."
    )
    return out


def merge_baselines(committed, suggested):
    """Apply a suggested (ratcheted) baseline onto the committed one.

    Monotone in the gate's favor: floors only ever rise, ceilings only
    ever fall (and never below their absolute ratchet guard).
    `threshold`, `_comment` and any key the gate does not know keep the
    committed values. Returns (merged, notes) where `notes` lists every
    suggested key that was ignored or newly added.
    """
    directions = {(s, k): d for s, k, _field, _agg, d in CHECKS}
    merged = json.loads(json.dumps(committed))
    notes = []
    for section, sec in suggested.items():
        if section in ("_comment", "threshold"):
            continue
        if not isinstance(sec, dict):
            notes.append(f"ignored non-section key `{section}`")
            continue
        for key, val in sec.items():
            direction = directions.get((section, key))
            if direction is None:
                notes.append(f"ignored unknown metric `{section}.{key}`")
                continue
            val = float(val)
            cur = merged.get(section, {}).get(key)
            if cur is None:
                merged.setdefault(section, {})[key] = round(val, 4)
                notes.append(
                    f"added `{section}.{key}` = {val:g} (absent from the committed baseline)"
                )
                continue
            cur = float(cur)
            if direction == "floor":
                new = max(cur, val)
            else:
                guard = RATCHET_CEILING_MIN.get(key, 0.0)
                new = max(min(cur, val), guard)
            merged[section][key] = round(new, 4)
    return merged, notes


def write_merge_summary(text, notes):
    """Put the ready-to-commit merged baseline into the GitHub job
    summary, when running under Actions."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [
        "## bench-gate baseline merge",
        "",
        "Ready-to-commit `BENCH_baseline.json` (committed ⊔ suggested: "
        "floors only rise, ceilings only fall):",
        "",
        "```json",
        text,
        "```",
    ]
    lines.extend(f"- {n}" for n in notes)
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def render_line(r):
    status = "OK" if r["ok"] else "REGRESSION"
    bound = "floor" if r["direction"] == "floor" else "ceiling"
    return (
        f"bench-gate {r['section'] + '.' + r['field']:<28} "
        f"{r['aggregate']:<7} = {r['current']:10.1f} ({r['rows']} rows) | "
        f"baseline {r['base']:10.1f} | {bound} {r['limit']:10.1f} | {status}"
    )


def write_summary(results, threshold, ratchet_path):
    """Append a markdown table (and staleness warnings) to the GitHub
    job summary, when running under Actions."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [
        "## bench-gate",
        "",
        f"Threshold: {threshold:.0%} against the committed `BENCH_baseline.json`.",
        "",
        "| metric | aggregate | observed | committed | limit | status |",
        "|---|---|---|---|---|---|",
    ]
    for r in results:
        status = "✅ OK" if r["ok"] else "❌ REGRESSION"
        lines.append(
            f"| `{r['section']}.{r['field']}` | {r['aggregate']} "
            f"({r['direction']}) | {r['current']:.1f} | {r['base']:.1f} | "
            f"{r['limit']:.1f} | {status} |"
        )
    stale = [r for r in results if r["stale"]]
    if stale:
        lines.append("")
        lines.append(
            f"⚠️ **{len(stale)} committed limit(s) are >{STALE_FACTOR:.0f}x stale** — "
            "the gate cannot catch regressions it should. Ratchet "
            "`BENCH_baseline.json` from the `suggested-baseline` artifact:"
        )
        for r in stale:
            lines.append(
                f"- `{r['section']}.{r['key']}`: committed {r['base']:g} vs "
                f"observed {r['current']:g} → suggest {suggest(r):g}"
            )
    if ratchet_path:
        lines.append("")
        lines.append(f"Suggested ratchet written to `{ratchet_path}`.")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--shard")
    ap.add_argument("--loadtest")
    ap.add_argument("--autoscale")
    ap.add_argument("--qos")
    ap.add_argument("--backend")
    ap.add_argument("--largefft")
    ap.add_argument("--hotpath")
    ap.add_argument("--tenants")
    ap.add_argument("--ntt")
    ap.add_argument(
        "--emit-ratchet",
        metavar="PATH",
        help="write the suggested (ratcheted) baseline JSON to PATH",
    )
    ap.add_argument(
        "--merge-artifact",
        metavar="PATH",
        help="standalone mode: merge a suggested-baseline artifact onto the "
        "committed baseline and print the ready-to-commit JSON",
    )
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)

    if args.merge_artifact:
        with open(args.merge_artifact) as f:
            suggested = json.load(f)
        merged, notes = merge_baselines(baseline, suggested)
        text = json.dumps(merged, indent=2)
        print(text)
        for n in notes:
            print(f"note: {n}", file=sys.stderr)
        write_merge_summary(text, notes)
        return

    missing = [n for n in ("shard", "loadtest") if not getattr(args, n)]
    if missing:
        ap.error(
            "the following arguments are required: "
            + ", ".join(f"--{m}" for m in missing)
        )
    files = {
        "shard": args.shard,
        "loadtest": args.loadtest,
        "autoscale": args.autoscale,
        "qos": args.qos,
        "backend": args.backend,
        "largefft": args.largefft,
        "hotpath": args.hotpath,
        "tenants": args.tenants,
        "ntt": args.ntt,
    }
    results, threshold = run_gate(baseline, files)

    failed = False
    for r in results:
        print(render_line(r))
        if not r["ok"]:
            failed = True
        elif r["stale"]:
            print(
                f"  note: `{r['section']}.{r['key']}` is >{STALE_FACTOR:.0f}x stale "
                f"(observed {r['current']:g} vs committed {r['base']:g}) — "
                f"ratchet BENCH_baseline.json toward {suggest(r):g}"
            )

    if args.emit_ratchet:
        with open(args.emit_ratchet, "w") as f:
            json.dump(ratchet_baseline(baseline, results), f, indent=2)
            f.write("\n")
        print(f"\nwrote suggested baseline ratchet to {args.emit_ratchet}")

    write_summary(results, threshold, args.emit_ratchet)

    if failed:
        print(
            f"\nFAIL: an aggregate crossed the committed baseline by more than "
            f"{threshold:.0%}.",
            file=sys.stderr,
        )
        sys.exit(1)
    print("\nbench-gate passed.")


if __name__ == "__main__":
    main()
