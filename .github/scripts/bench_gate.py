#!/usr/bin/env python3
"""CI bench-regression gate.

Reads the quick-mode JSON rows written by `benches/shard.rs`
(`jobs_per_s` per row) and `benches/loadtest.rs` (`achieved_rps` per
row), reduces each to an aggregate throughput (geometric mean across
rows), and fails when either aggregate falls more than the threshold
below the committed `BENCH_baseline.json`.

The baseline is a conservative floor, not a point estimate: CI runners
are noisy, so the gate only trips on real cliffs (default threshold:
15%). When a run lands far above the floor, the gate prints the values
to ratchet the baseline up to (baseline * 1.0 is always safe to raise
toward ~80% of a typical run).

Usage:
    bench_gate.py --baseline BENCH_baseline.json \
                  --shard BENCH_shard.json --loadtest BENCH_loadtest.json
"""

import argparse
import json
import math
import sys


def geomean(values):
    vals = [v for v in values if v > 0]
    if not vals:
        return 0.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def aggregate(path, field):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list) or not rows:
        raise SystemExit(f"{path}: expected a non-empty JSON array of bench rows")
    missing = [r for r in rows if field not in r]
    if missing:
        raise SystemExit(f"{path}: {len(missing)} rows lack the `{field}` field")
    return geomean(r[field] for r in rows), len(rows)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--shard", required=True)
    ap.add_argument("--loadtest", required=True)
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    threshold = float(baseline.get("threshold", 0.15))

    checks = [
        ("shard", args.shard, "jobs_per_s", baseline["shard"]["agg_jobs_per_s"]),
        ("loadtest", args.loadtest, "achieved_rps", baseline["loadtest"]["agg_achieved_rps"]),
    ]

    failed = False
    for name, path, field, base in checks:
        cur, nrows = aggregate(path, field)
        floor = base * (1.0 - threshold)
        status = "OK" if cur >= floor else "REGRESSION"
        print(
            f"bench-gate {name:<9} aggregate {field} = {cur:10.1f} "
            f"({nrows} rows) | baseline {base:10.1f} | floor {floor:10.1f} | {status}"
        )
        if cur < floor:
            failed = True
        elif base > 0 and cur > base * 1.5:
            print(
                f"  note: {name} runs {cur / base:.1f}x above the committed floor — "
                f"consider ratcheting BENCH_baseline.json up toward {0.8 * cur:.0f}"
            )

    if failed:
        print(
            f"\nFAIL: aggregate throughput regressed more than "
            f"{threshold:.0%} below the committed baseline.",
            file=sys.stderr,
        )
        sys.exit(1)
    print("\nbench-gate passed.")


if __name__ == "__main__":
    main()
