#!/usr/bin/env python3
"""Relative-link checker for the CI docs job.

Walks the markdown files given on the command line (directories are
expanded to every ``*.md`` they contain), extracts inline links and
images (``[text](target)`` / ``![alt](target)``), and fails when a
*relative* target does not exist on disk. Anchors within this repo's
own files (``file.md#section`` or bare ``#section``) are checked
against the target file's ATX headings using GitHub's slug rules
(lowercase, punctuation stripped, spaces to hyphens).

External URLs (``http://``, ``https://``, ``mailto:``) are deliberately
out of scope: they rot on the far end's schedule, not this repo's, and
checking them makes CI flaky. No third-party dependencies.

Usage:
    check_links.py README.md ROADMAP.md docs
"""

import pathlib
import re
import sys

# Inline links/images. Targets never contain whitespace or a closing
# paren in this repo's docs, which keeps the pattern honest about
# nested-paren edge cases instead of mis-parsing them.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^()\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:")


def slug(heading):
    """GitHub's heading-to-anchor slug: strip markdown emphasis/code
    markers and punctuation, lowercase, hyphenate spaces."""
    text = re.sub(r"[`*_]", "", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings
    text = re.sub(r"[^\w\s-]", "", text.lower())
    return re.sub(r"\s+", "-", text.strip())


def collect_md(args):
    files = []
    for arg in args:
        p = pathlib.Path(arg)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            raise SystemExit(f"check_links: no such file or directory: {arg}")
    return files


def links_in(path):
    """(line number, target) pairs for every inline link outside code
    fences."""
    out = []
    in_fence = False
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            out.append((lineno, m.group(1)))
    return out


def anchors_in(path, cache={}):
    if path not in cache:
        slugs = set()
        in_fence = False
        for line in path.read_text().splitlines():
            if CODE_FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            m = None if in_fence else HEADING_RE.match(line)
            if m:
                slugs.add(slug(m.group(1)))
        cache[path] = slugs
    return cache[path]


def check(files):
    errors = []
    for path in files:
        for lineno, target in links_in(path):
            if target.startswith(EXTERNAL):
                continue
            base, _, frag = target.partition("#")
            dest = path.parent / base if base else path
            if not dest.exists():
                errors.append(f"{path}:{lineno}: broken link `{target}` (no {dest})")
            elif frag and dest.suffix == ".md" and slug(frag) not in anchors_in(dest):
                errors.append(
                    f"{path}:{lineno}: broken anchor `{target}` (no heading "
                    f"`#{frag}` in {dest})"
                )
    return errors


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        raise SystemExit("usage: check_links.py FILE_OR_DIR [...]")
    files = collect_md(argv)
    errors = check(files)
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        raise SystemExit(1)
    print(f"check_links: {len(files)} markdown files, all relative links resolve.")


if __name__ == "__main__":
    main()
