"""L2 JAX compute graph: the full radix-4 FFT built from the L1 Pallas
stage kernel, mirroring the eGPU program structure (log4(N) in-place DIF
passes + a final digit-reversed reorder, §3.2).

Build-time only: `aot.py` lowers `make_fft(n)` once per size to HLO text
and the rust runtime executes the artifact — Python never runs on the
request path.
"""

import functools

import numpy as np
import jax
import jax.numpy as jnp

from compile.kernels import fft_stage, ref

RADIX = 4


def plan_strides(n: int) -> list[int]:
    """Strides of the log4(N) DIF passes: N/4, N/16, …, 1."""
    assert n >= 16 and 4 ** int(round(np.log(n) / np.log(4))) == n, (
        f"L2 model supports 4^k sizes, got {n}"
    )
    strides = []
    s = n // RADIX
    while s >= 1:
        strides.append(s)
        s //= RADIX
    return strides


def fft(xr, xi, *, interpret=True):
    """Forward complex FFT of float32[N] (re, im) pairs.

    Each pass reshapes the flat array to (G, 4, S) — the same
    thread→index geometry as Figure 2 of the paper — and calls the
    Pallas stage kernel; twiddle tables are compile-time constants, as
    in the eGPU's preloaded shared memory.
    """
    n = xr.shape[0]
    for s in plan_strides(n):
        g = n // (RADIX * s)
        twr, twi = ref.twiddles(s)
        xr4 = xr.reshape(g, RADIX, s)
        xi4 = xi.reshape(g, RADIX, s)
        yr, yi = fft_stage.radix4_stage(xr4, xi4, jnp.asarray(twr), jnp.asarray(twi),
                                        interpret=interpret)
        xr = yr.reshape(n)
        xi = yi.reshape(n)
    return _digit_reverse(xr), _digit_reverse(xi)


def _digit_reverse(x):
    """Base-4 digit reversal as reshape→transpose→reshape — XLA lowers
    this to a copy with a permuted layout, far cheaper than the gather a
    `x[perm]` formulation emits (EXPERIMENTS.md §Perf, L2)."""
    n = x.shape[0]
    k = n.bit_length() // 2  # log4(n), n = 4^k
    axes = tuple(reversed(range(k)))
    return x.reshape((RADIX,) * k).transpose(axes).reshape(n)


@functools.cache
def make_fft(n: int):
    """A jitted f(xr, xi) -> (yr, yi) for one FFT size."""

    @jax.jit
    def f(xr, xi):
        return fft(xr, xi)

    return f


@functools.cache
def make_stage(g: int, s: int):
    """A jitted single radix-4 stage over (G, 4, S) blocks (the
    kernel-granularity artifact used by runtime smoke tests)."""
    twr, twi = ref.twiddles(s)

    @jax.jit
    def f(xr, xi):
        return fft_stage.radix4_stage(
            xr, xi, jnp.asarray(twr), jnp.asarray(twi)
        )

    return f
