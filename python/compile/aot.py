"""AOT lowering: JAX model → HLO *text* artifacts for the rust runtime.

HLO text (NOT `lowered.compile()` / serialized protos) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

FFT_SIZES = (256, 1024, 4096)
# kernel-granularity artifact: one 4096-point pass-1 stage
STAGE_SHAPE = (1, 4, 1024)  # (G, 4, S)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big
    # constants as `constant({...})`, which the text parser then
    # ZERO-FILLS — silently zeroing the twiddle tables and the
    # digit-reversal gather indices.
    return comp.as_hlo_text(True)


def lower_fft(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    return to_hlo_text(model.make_fft(n).lower(spec, spec))


def lower_stage(g: int, s: int) -> str:
    spec = jax.ShapeDtypeStruct((g, 4, s), jnp.float32)
    return to_hlo_text(model.make_stage(g, s).lower(spec, spec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    for n in FFT_SIZES:
        text = lower_fft(n)
        path = out / f"fft{n}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")

    g, _, s = STAGE_SHAPE
    text = lower_stage(g, s)
    path = out / "fft_stage.hlo.txt"
    path.write_text(text)
    print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
