"""Pure-jnp correctness oracles for the Pallas kernel and the L2 model.

These never go through Pallas: `stage_ref` is the straight-line jnp
formulation of one radix-4 DIF pass, and `fft_ref` wraps `jnp.fft.fft`.
pytest checks kernel == stage_ref and model == fft_ref.
"""

import numpy as np
import jax.numpy as jnp


def twiddles(stride: int, radix: int = 4) -> tuple[np.ndarray, np.ndarray]:
    """W_{radix·stride}^{r·m} for m = 1..radix, r = 0..stride.

    Returns (twr, twi) as float32[(radix-1), stride] — the same table the
    eGPU preloads into shared memory (rust/src/fft/twiddle.rs).
    """
    l = radix * stride
    m = np.arange(1, radix)[:, None]
    r = np.arange(stride)[None, :]
    w = np.exp(-2j * np.pi * (m * r % l) / l)
    return (
        w.real.astype(np.float32),
        w.imag.astype(np.float32),
    )


def stage_ref(xr, xi, twr, twi):
    """One radix-4 DIF pass over float32[G, 4, S] (oracle for the
    Pallas kernel — same math, no pallas_call)."""
    x = xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64)
    a, b, c, d = x[:, 0], x[:, 1], x[:, 2], x[:, 3]
    t0, t1 = a + c, a - c
    t2, t3 = b + d, b - d
    y0 = t0 + t2
    y1 = t1 - 1j * t3
    y2 = t0 - t2
    y3 = t1 + 1j * t3
    tw = twr.astype(jnp.complex64) + 1j * twi.astype(jnp.complex64)
    y = jnp.stack([y0, y1 * tw[0], y2 * tw[1], y3 * tw[2]], axis=1)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def fft_ref(xr, xi):
    """Full FFT oracle: jnp.fft.fft over float32[N] pairs."""
    y = jnp.fft.fft(xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64))
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def digit_reverse_indices(n: int, radix: int = 4) -> np.ndarray:
    """perm[i] = in-place index whose value is natural-order bin i after
    all DIF passes (matches FftPlan::natural_of_inplace in rust)."""
    passes = []
    rem = n
    while rem > 1:
        assert rem % radix == 0, (n, radix)
        passes.append(radix)
        rem //= radix
    n_passes = len(passes)
    strides = [radix ** (n_passes - 1 - p) for p in range(n_passes)]
    nat = np.zeros(n, dtype=np.int64)
    weight = 1
    for stride, r in zip(strides, passes):
        nat += ((np.arange(n) // stride) % r) * weight
        weight *= r
    perm = np.empty(n, dtype=np.int64)
    perm[nat] = np.arange(n)
    return perm
