"""L1 Pallas kernel: one radix-4 DIF FFT pass (the compute hot-spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's eGPU
runs one dragonfly per SIMT thread with the pass working set resident in
the SM's banked shared memory. On TPU-flavoured Pallas the analogue is a
grid step whose (GB, 4, S) block lives in VMEM (the scratchpad analogue
of the 64 KB shared memory); the butterfly is bandwidth-bound
elementwise math, so it targets the VPU rather than the MXU, exactly as
the eGPU's DSP-block FP path rather than its (removed) integer
multipliers.

Blocking (§Perf, L1): a grid step processes GB butterfly groups at
once, sized so a block stays ≈16 KB per operand (VMEM-scale) while the
grid stays shallow — one gridstep per pass for every size the paper
reports. The eGPU analogue of GB is the wavefront depth.

Lowered with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Per-block element budget: GB·4·S ≤ 4·MAX_BLOCK (≈16 KB per f32 array),
# the VMEM-scale working set of one grid step.
MAX_BLOCK = 1024
# Largest supported stride (one (1, 4, MAX_S) block is the minimum).
MAX_S = 1024


def _block_groups(g: int, s: int) -> int:
    """Butterfly groups per grid step: fill the block budget, divide G."""
    gb = max(1, MAX_BLOCK // s)
    return min(g, gb)


def _stage_kernel(xr_ref, xi_ref, twr_ref, twi_ref, yr_ref, yi_ref):
    """Radix-4 DIF dragonfly + twiddle over one (GB, 4, S) block.

    Mirrors the eGPU kernel instruction-for-instruction (see
    rust/src/fft/codegen.rs kernel_radix4): 8 complex add/sub with the
    ±j rotation folded into operand routing, then three complex
    multiplies by the per-position twiddles W_{4S}^{r·m} (broadcast over
    the GB leading axis, like the shared twiddle table across threads).
    """
    xr = xr_ref[...]  # (GB, 4, S)
    xi = xi_ref[...]
    twr = twr_ref[...]  # (3, S)
    twi = twi_ref[...]

    t0r = xr[:, 0] + xr[:, 2]
    t0i = xi[:, 0] + xi[:, 2]
    t1r = xr[:, 0] - xr[:, 2]
    t1i = xi[:, 0] - xi[:, 2]
    t2r = xr[:, 1] + xr[:, 3]
    t2i = xi[:, 1] + xi[:, 3]
    t3r = xr[:, 1] - xr[:, 3]
    t3i = xi[:, 1] - xi[:, 3]

    y0r = t0r + t2r
    y0i = t0i + t2i
    y2r = t0r - t2r
    y2i = t0i - t2i
    # Y1 = t1 - j t3 ; Y3 = t1 + j t3 (pure add/sub, §3.1)
    y1r = t1r + t3i
    y1i = t1i - t3r
    y3r = t1r - t3i
    y3i = t1i + t3r

    # twiddles on outputs 1..3 (output 0 is twiddle-free); (GB, S)·(S,)
    o1r = y1r * twr[0] - y1i * twi[0]
    o1i = y1r * twi[0] + y1i * twr[0]
    o2r = y2r * twr[1] - y2i * twi[1]
    o2i = y2r * twi[1] + y2i * twr[1]
    o3r = y3r * twr[2] - y3i * twi[2]
    o3i = y3r * twi[2] + y3i * twr[2]

    yr_ref[...] = jnp.stack([y0r, o1r, o2r, o3r], axis=1)
    yi_ref[...] = jnp.stack([y0i, o1i, o2i, o3i], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def radix4_stage(xr, xi, twr, twi, *, interpret=True):
    """Apply one radix-4 DIF pass.

    Args:
      xr, xi: float32[G, 4, S] — G blocks of 4 butterfly legs × stride S.
      twr, twi: float32[3, S] — twiddles W_{4S}^{r·m}, m = 1..3 (shared
        by every block, like the eGPU's shared-memory twiddle table).

    Returns:
      (yr, yi): float32[G, 4, S] with the pass applied in place.
    """
    g, four, s = xr.shape
    assert four == 4 and s <= MAX_S, (g, four, s)
    assert twr.shape == (3, s), twr.shape
    gb = _block_groups(g, s)
    assert g % gb == 0, (g, gb)
    out_shape = [
        jax.ShapeDtypeStruct(xr.shape, jnp.float32),
        jax.ShapeDtypeStruct(xi.shape, jnp.float32),
    ]
    block = pl.BlockSpec((gb, 4, s), lambda i: (i, 0, 0))
    tw_block = pl.BlockSpec((3, s), lambda i: (0, 0))
    kernel = pl.pallas_call(
        _stage_kernel,
        grid=(g // gb,),
        in_specs=[block, block, tw_block, tw_block],
        out_specs=[block, block],
        out_shape=out_shape,
        interpret=interpret,
    )
    return kernel(xr, xi, twr, twi)
