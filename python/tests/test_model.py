"""L2 correctness: the stage-composed FFT model vs jnp.fft, plus the
digit-reversal permutation and AOT lowering smoke tests."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rel_rms(got, want):
    got = np.asarray(got, dtype=np.float64)
    want = np.asarray(want, dtype=np.float64)
    denom = np.sqrt(np.mean(want**2)) + 1e-30
    return np.sqrt(np.mean((got - want) ** 2)) / denom


@pytest.mark.parametrize("n", [16, 64, 256, 1024, 4096])
def test_fft_matches_jnp(n):
    rng = np.random.default_rng(n)
    xr = rng.standard_normal(n, dtype=np.float32)
    xi = rng.standard_normal(n, dtype=np.float32)
    got_r, got_i = model.make_fft(n)(jnp.asarray(xr), jnp.asarray(xi))
    want_r, want_i = ref.fft_ref(jnp.asarray(xr), jnp.asarray(xi))
    assert rel_rms(got_r, want_r) < 1e-5
    assert rel_rms(got_i, want_i) < 1e-5


def test_fft_impulse():
    n = 256
    xr = np.zeros(n, dtype=np.float32)
    xr[0] = 1.0
    xi = np.zeros_like(xr)
    yr, yi = model.make_fft(n)(jnp.asarray(xr), jnp.asarray(xi))
    np.testing.assert_allclose(np.asarray(yr), 1.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(yi), 0.0, atol=1e-6)


def test_fft_single_tone():
    n = 64
    k = 5
    t = np.arange(n)
    x = np.exp(2j * np.pi * k * t / n).astype(np.complex64)
    yr, yi = model.make_fft(n)(
        jnp.asarray(x.real.astype(np.float32)), jnp.asarray(x.imag.astype(np.float32))
    )
    mag = np.abs(np.asarray(yr) + 1j * np.asarray(yi))
    assert mag[k] == pytest.approx(n, rel=1e-4)
    mag[k] = 0
    assert mag.max() < 1e-2


def test_digit_reverse_is_permutation():
    for n in [16, 64, 256, 1024]:
        perm = ref.digit_reverse_indices(n)
        assert sorted(perm) == list(range(n))
        # base-4 digit reversal is an involution
        np.testing.assert_array_equal(perm[perm], np.arange(n))


def test_plan_strides():
    assert model.plan_strides(256) == [64, 16, 4, 1]
    assert model.plan_strides(4096) == [1024, 256, 64, 16, 4, 1]
    with pytest.raises(AssertionError):
        model.plan_strides(512)  # not a power of 4


def test_aot_lowering_produces_hlo_text():
    from compile import aot

    text = aot.lower_fft(256)
    assert "HloModule" in text
    assert "f32[256]" in text
    stage = aot.lower_stage(1, 1024)
    assert "HloModule" in stage
    assert "f32[1,4,1024]" in stage
