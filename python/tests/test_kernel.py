"""L1 correctness: Pallas stage kernel vs the pure-jnp oracle.

The hypothesis sweep walks the (G, S) shape space and random data; exact
agreement is expected because kernel and oracle perform the same f32
operations (stage_ref computes via complex64, so tolerance is 1 ulp-ish).
"""

import numpy as np
import pytest

try:  # hypothesis is not in the offline image; fall back to a fixed sweep
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# Skip (not error) the whole module when the JAX/Pallas stack is absent
# or broken: these are L1 kernel tests and meaningless without it.
pytest.importorskip(
    "jax", reason="JAX is required for the Pallas kernel tests", exc_type=ImportError
)

import jax.numpy as jnp

from compile.kernels import fft_stage, ref

RNG = np.random.default_rng(0xE69D0)


def run_stage(g, s, seed):
    rng = np.random.default_rng(seed)
    xr = rng.standard_normal((g, 4, s), dtype=np.float32)
    xi = rng.standard_normal((g, 4, s), dtype=np.float32)
    twr, twi = ref.twiddles(s)
    got_r, got_i = fft_stage.radix4_stage(
        jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(twr), jnp.asarray(twi)
    )
    want_r, want_i = ref.stage_ref(
        jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(twr), jnp.asarray(twi)
    )
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "g,s",
    [
        (1, 1),      # last pass of a 4-point FFT
        (1, 64),     # pass 1 of 256
        (4, 16),     # pass 2 of 256
        (64, 1),     # last pass of 256
        (1, 1024),   # pass 1 of 4096
        (256, 4),    # pass 5 of 4096
    ],
)
def test_stage_matches_ref_paper_shapes(g, s):
    run_stage(g, s, seed=g * 10007 + s)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        log_g=st.integers(min_value=0, max_value=6),
        log_s=st.integers(min_value=0, max_value=8),
        seed=st.integers(min_value=0, max_value=2**32 - 1),
    )
    def test_stage_matches_ref_hypothesis(log_g, log_s, seed):
        run_stage(2**log_g, 2**log_s, seed)

else:  # deterministic stand-in covering the same (G, S) shape space

    @pytest.mark.parametrize("log_g", [0, 2, 4, 6])
    @pytest.mark.parametrize("log_s", [0, 3, 6, 8])
    def test_stage_matches_ref_sweep(log_g, log_s):
        run_stage(2**log_g, 2**log_s, seed=log_g * 1009 + log_s)


def test_stage_impulse():
    # impulse in leg 0 -> all four outputs equal the impulse (twiddles
    # only touch outputs 1..3, which see W^0 at r=0)
    s = 4
    xr = np.zeros((1, 4, s), dtype=np.float32)
    xi = np.zeros_like(xr)
    xr[0, 0, 0] = 1.0
    twr, twi = ref.twiddles(s)
    yr, yi = fft_stage.radix4_stage(
        jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(twr), jnp.asarray(twi)
    )
    np.testing.assert_allclose(np.asarray(yr)[0, :, 0], 1.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(yi)[0, :, 0], 0.0, atol=1e-6)


def test_stage_linearity():
    g, s = 2, 8
    rng = np.random.default_rng(7)
    a = rng.standard_normal((2, g, 4, s), dtype=np.float32)
    b = rng.standard_normal((2, g, 4, s), dtype=np.float32)
    twr, twi = (jnp.asarray(t) for t in ref.twiddles(s))
    ya = fft_stage.radix4_stage(jnp.asarray(a[0]), jnp.asarray(a[1]), twr, twi)
    yb = fft_stage.radix4_stage(jnp.asarray(b[0]), jnp.asarray(b[1]), twr, twi)
    ys = fft_stage.radix4_stage(
        jnp.asarray(a[0] + b[0]), jnp.asarray(a[1] + b[1]), twr, twi
    )
    np.testing.assert_allclose(ys[0], ya[0] + yb[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ys[1], ya[1] + yb[1], rtol=1e-4, atol=1e-4)


def test_twiddle_table_properties():
    twr, twi = ref.twiddles(16)
    assert twr.shape == (3, 16)
    # r = 0 column is W^0 = 1
    np.testing.assert_allclose(twr[:, 0], 1.0, atol=1e-7)
    np.testing.assert_allclose(twi[:, 0], 0.0, atol=1e-7)
    # unit magnitude everywhere
    np.testing.assert_allclose(twr**2 + twi**2, 1.0, atol=1e-6)
