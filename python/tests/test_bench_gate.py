"""Unit tests for the CI bench-regression gate
(.github/scripts/bench_gate.py): pass/fail at the 15% threshold in both
check directions, missing-key handling, and the --emit-ratchet output.

The script lives outside any package (``.github`` is not importable),
so it is loaded by file path.
"""

import importlib.util
import json
import pathlib

import pytest

SCRIPT = (
    pathlib.Path(__file__).resolve().parents[2] / ".github" / "scripts" / "bench_gate.py"
)


def _load():
    spec = importlib.util.spec_from_file_location("bench_gate", SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bench_gate = _load()


def baseline(threshold=0.15, autoscale=True):
    base = {
        "threshold": threshold,
        "shard": {"agg_jobs_per_s": 100.0},
        "loadtest": {"agg_achieved_rps": 200.0},
    }
    if autoscale:
        base["autoscale"] = {
            "agg_recovered_rps": 100.0,
            "shed_rate_after_max": 0.5,
            "p99_recovery_ms_max": 1000.0,
        }
    return base


def write_rows(tmp_path, name, rows):
    path = tmp_path / name
    path.write_text(json.dumps(rows))
    return str(path)


def files_for(tmp_path, shard_jps=100.0, rps=200.0, recovered=100.0, shed=0.1, p99=500.0):
    return {
        "shard": write_rows(tmp_path, "shard.json", [{"jobs_per_s": shard_jps}]),
        "loadtest": write_rows(tmp_path, "loadtest.json", [{"achieved_rps": rps}]),
        "autoscale": write_rows(
            tmp_path,
            "autoscale.json",
            [{"recovered_rps": recovered, "shed_rate_after": shed, "p99_recovery_ms": p99}],
        ),
    }


def by_key(results, key):
    return next(r for r in results if r["key"] == key)


class TestThreshold:
    def test_passes_within_15_percent(self, tmp_path):
        # 14% below the floor baseline: inside the threshold
        results, threshold = bench_gate.run_gate(
            baseline(), files_for(tmp_path, shard_jps=86.0)
        )
        assert threshold == 0.15
        assert all(r["ok"] for r in results)

    def test_fails_beyond_15_percent(self, tmp_path):
        # 16% below the floor baseline: a real regression
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shard_jps=84.0))
        r = by_key(results, "agg_jobs_per_s")
        assert not r["ok"]
        assert by_key(results, "agg_achieved_rps")["ok"], "other checks unaffected"

    def test_ceiling_fails_above_threshold(self, tmp_path):
        # shed_rate_after 0.6 > 0.5 * 1.15 ceiling
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shed=0.6))
        assert not by_key(results, "shed_rate_after_max")["ok"]
        # 0.55 <= 0.575 stays inside
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shed=0.55))
        assert by_key(results, "shed_rate_after_max")["ok"]

    def test_geomean_aggregates_rows(self, tmp_path):
        files = files_for(tmp_path)
        files["shard"] = write_rows(
            tmp_path, "shard2.json", [{"jobs_per_s": 50.0}, {"jobs_per_s": 200.0}]
        )
        results, _ = bench_gate.run_gate(baseline(), files)
        r = by_key(results, "agg_jobs_per_s")
        assert r["current"] == pytest.approx(100.0)  # sqrt(50 * 200)
        assert r["rows"] == 2


class TestMissingInputs:
    def test_rows_missing_the_field_raise(self, tmp_path):
        files = files_for(tmp_path)
        files["shard"] = write_rows(tmp_path, "bad.json", [{"wrong_field": 1.0}])
        with pytest.raises(SystemExit, match="lack the `jobs_per_s` field"):
            bench_gate.run_gate(baseline(), files)

    def test_empty_rows_raise(self, tmp_path):
        files = files_for(tmp_path)
        files["loadtest"] = write_rows(tmp_path, "empty.json", [])
        with pytest.raises(SystemExit, match="non-empty JSON array"):
            bench_gate.run_gate(baseline(), files)

    def test_gated_section_without_file_raises(self, tmp_path):
        files = files_for(tmp_path)
        files["autoscale"] = None
        with pytest.raises(SystemExit, match="no --autoscale file"):
            bench_gate.run_gate(baseline(), files)

    def test_ungated_section_is_skipped(self, tmp_path):
        # baseline without an autoscale section: no file needed
        files = files_for(tmp_path)
        files["autoscale"] = None
        results, _ = bench_gate.run_gate(baseline(autoscale=False), files)
        assert all(r["section"] != "autoscale" for r in results)


class TestRatchet:
    def test_floor_ratchets_up_to_80_percent_of_observed(self, tmp_path):
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shard_jps=1000.0))
        r = by_key(results, "agg_jobs_per_s")
        assert r["stale"], "10x above the floor is >2x stale"
        assert bench_gate.suggest(r) == pytest.approx(800.0)

    def test_floor_never_ratchets_down(self, tmp_path):
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shard_jps=90.0))
        r = by_key(results, "agg_jobs_per_s")
        assert not r["stale"]
        assert bench_gate.suggest(r) == pytest.approx(100.0), "keeps the committed floor"

    def test_ceiling_tightens_but_keeps_a_guard_band(self, tmp_path):
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shed=0.1))
        r = by_key(results, "shed_rate_after_max")
        assert bench_gate.suggest(r) == pytest.approx(0.125), "1.25x observed"
        # a perfect 0.0 observation must not weld the gate shut
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shed=0.0))
        r = by_key(results, "shed_rate_after_max")
        assert bench_gate.suggest(r) == pytest.approx(0.02), "absolute guard minimum"

    def test_ceiling_guard_is_stable_across_repeated_ratchets(self, tmp_path):
        # repeated lucky-zero observations must converge to the absolute
        # minimum, not decay geometrically toward zero
        base = baseline()
        for _ in range(3):
            results, _ = bench_gate.run_gate(base, files_for(tmp_path, shed=0.0, p99=0.0))
            base = bench_gate.ratchet_baseline(base, results)
        assert base["autoscale"]["shed_rate_after_max"] == pytest.approx(0.02)
        assert base["autoscale"]["p99_recovery_ms_max"] == pytest.approx(250.0)

    def test_ratchet_baseline_preserves_structure(self, tmp_path):
        base = baseline()
        results, _ = bench_gate.run_gate(base, files_for(tmp_path, shard_jps=1000.0))
        out = bench_gate.ratchet_baseline(base, results)
        assert out["shard"]["agg_jobs_per_s"] == pytest.approx(800.0)
        assert out["threshold"] == 0.15
        assert "suggested baseline" in out["_comment"].lower()
        assert base["shard"]["agg_jobs_per_s"] == 100.0, "input baseline untouched"


class TestMain:
    def argv(self, tmp_path, files, extra=()):
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(baseline()))
        return [
            "--baseline",
            str(base_path),
            "--shard",
            files["shard"],
            "--loadtest",
            files["loadtest"],
            "--autoscale",
            files["autoscale"],
            *extra,
        ]

    def test_main_passes_and_emits_ratchet(self, tmp_path, capsys):
        out_path = tmp_path / "suggested.json"
        files = files_for(tmp_path, shard_jps=1000.0)
        bench_gate.main(self.argv(tmp_path, files, ["--emit-ratchet", str(out_path)]))
        captured = capsys.readouterr().out
        assert "bench-gate passed" in captured
        assert ">2x stale" in captured
        suggested = json.loads(out_path.read_text())
        assert suggested["shard"]["agg_jobs_per_s"] == pytest.approx(800.0)

    def test_main_exits_nonzero_on_regression(self, tmp_path, capsys):
        files = files_for(tmp_path, shard_jps=10.0)
        with pytest.raises(SystemExit) as exc:
            bench_gate.main(self.argv(tmp_path, files))
        assert exc.value.code == 1
        assert "REGRESSION" in capsys.readouterr().out

    def test_main_writes_github_step_summary(self, tmp_path, monkeypatch, capsys):
        summary = tmp_path / "summary.md"
        monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
        files = files_for(tmp_path, shard_jps=1000.0)
        bench_gate.main(self.argv(tmp_path, files))
        capsys.readouterr()
        text = summary.read_text()
        assert "## bench-gate" in text
        assert "stale" in text
        assert "shard.jobs_per_s" in text
