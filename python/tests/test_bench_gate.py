"""Unit tests for the CI bench-regression gate
(.github/scripts/bench_gate.py): pass/fail at the 15% threshold in both
check directions, missing-key handling, the --emit-ratchet output, and
the standalone --merge-artifact baseline merge.

The script lives outside any package (``.github`` is not importable),
so it is loaded by file path.
"""

import importlib.util
import json
import pathlib

import pytest

SCRIPT = (
    pathlib.Path(__file__).resolve().parents[2] / ".github" / "scripts" / "bench_gate.py"
)


def _load():
    spec = importlib.util.spec_from_file_location("bench_gate", SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bench_gate = _load()


def baseline(
    threshold=0.15,
    autoscale=True,
    qos=True,
    backend=True,
    largefft=True,
    hotpath=True,
    tenants=True,
    ntt=True,
):
    base = {
        "threshold": threshold,
        "shard": {"agg_jobs_per_s": 100.0},
        "loadtest": {"agg_achieved_rps": 200.0},
    }
    if autoscale:
        base["autoscale"] = {
            "agg_recovered_rps": 100.0,
            "shed_rate_after_max": 0.5,
            "p99_recovery_ms_max": 1000.0,
        }
    if qos:
        base["qos"] = {
            "agg_qos_rps": 50.0,
            "share_err_max": 0.2,
        }
    if backend:
        base["backend"] = {
            "agg_routed_rps": 100.0,
            "validate_overhead_max": 0.4,
        }
    if largefft:
        base["largefft"] = {"agg_mp_rps": 1.0}
    if hotpath:
        base["hotpath"] = {"ns_per_job_max": 100000.0}
    if tenants:
        base["tenants"] = {
            "agg_tenant_rps": 50.0,
            "p99_interference_max": 8.0,
        }
    if ntt:
        base["ntt"] = {"agg_ntt_rps": 50.0}
    return base


def write_rows(tmp_path, name, rows):
    path = tmp_path / name
    path.write_text(json.dumps(rows))
    return str(path)


def qos_rows(qos_rps=50.0, share_err=0.05):
    """Per-class rows, the shape benches/qos.rs emits (one row per
    class plus crossover rows with share_err 0)."""
    return [
        {"class": "gold", "achieved_rps": qos_rps * 2, "share_err": share_err},
        {"class": "bronze", "achieved_rps": qos_rps / 2, "share_err": share_err / 2},
        {"class": "all", "achieved_rps": qos_rps, "share_err": 0.0},
    ]


def largefft_rows(mp_rps=2.0):
    """Per-size, per-strategy rows, the shape benches/largefft.rs
    emits (pipelined and serialize-passes rows for each large N)."""
    return [
        {"points": 8192, "mode": "pipelined", "mp_rps": mp_rps * 2},
        {"points": 8192, "mode": "serialized", "mp_rps": mp_rps / 2},
        {"points": 65536, "mode": "pipelined", "mp_rps": mp_rps},
    ]


def hotpath_rows(ns_per_job=50000.0):
    """Per-config rows, the shape benches/hotpath.rs emits (one row per
    no-op service configuration)."""
    return [
        {"config": "pool2_noop", "ns_per_job": ns_per_job / 2, "lease_hits": 2000},
        {"config": "shard2_noop", "ns_per_job": ns_per_job, "lease_hits": 2000},
    ]


def tenants_rows(tenant_rps=100.0, interference=2.0):
    """Per-tenant rows, the shape benches/tenants.rs emits (the victim
    row carries the interference ratio; the abuser row reports 0 so the
    gate's max() reads only the victim)."""
    return [
        {"tenant": "victim", "tenant_rps": tenant_rps / 2, "p99_interference": interference},
        {"tenant": "abuser", "tenant_rps": tenant_rps * 2, "p99_interference": 0.0},
    ]


def ntt_rows(ntt_rps=100.0):
    """Per-config rows, the shape benches/ntt.rs emits (saturated
    single-pass legs plus the four-step multipass leg)."""
    return [
        {"config": "saturated_2shard_1024", "ntt_rps": ntt_rps * 2},
        {"config": "saturated_2shard_4096", "ntt_rps": ntt_rps},
        {"config": "multipass_65536", "ntt_rps": ntt_rps / 2},
    ]


def backend_rows(routed_rps=200.0, overhead=0.1):
    """Per-config rows, the shape benches/backend.rs emits (pinned and
    routed throughput rows plus validation-sampling rows)."""
    return [
        {"config": "pinned_sim", "routed_rps": routed_rps / 2, "validate_overhead": 0.0},
        {"config": "routed_fastpath", "routed_rps": routed_rps * 2, "validate_overhead": 0.0},
        {"config": "validate_10pct", "routed_rps": routed_rps, "validate_overhead": overhead},
    ]


def files_for(
    tmp_path,
    shard_jps=100.0,
    rps=200.0,
    recovered=100.0,
    shed=0.1,
    p99=500.0,
    qos_rps=50.0,
    share_err=0.05,
    routed_rps=200.0,
    overhead=0.1,
    mp_rps=2.0,
    ns_per_job=50000.0,
    tenant_rps=100.0,
    interference=2.0,
    ntt_rps=100.0,
):
    return {
        "shard": write_rows(tmp_path, "shard.json", [{"jobs_per_s": shard_jps}]),
        "loadtest": write_rows(tmp_path, "loadtest.json", [{"achieved_rps": rps}]),
        "autoscale": write_rows(
            tmp_path,
            "autoscale.json",
            [{"recovered_rps": recovered, "shed_rate_after": shed, "p99_recovery_ms": p99}],
        ),
        "qos": write_rows(tmp_path, "qos.json", qos_rows(qos_rps, share_err)),
        "backend": write_rows(
            tmp_path, "backend.json", backend_rows(routed_rps, overhead)
        ),
        "largefft": write_rows(tmp_path, "largefft.json", largefft_rows(mp_rps)),
        "hotpath": write_rows(tmp_path, "hotpath.json", hotpath_rows(ns_per_job)),
        "tenants": write_rows(
            tmp_path, "tenants.json", tenants_rows(tenant_rps, interference)
        ),
        "ntt": write_rows(tmp_path, "ntt.json", ntt_rows(ntt_rps)),
    }


def by_key(results, key):
    return next(r for r in results if r["key"] == key)


class TestThreshold:
    def test_passes_within_15_percent(self, tmp_path):
        # 14% below the floor baseline: inside the threshold
        results, threshold = bench_gate.run_gate(
            baseline(), files_for(tmp_path, shard_jps=86.0)
        )
        assert threshold == 0.15
        assert all(r["ok"] for r in results)

    def test_fails_beyond_15_percent(self, tmp_path):
        # 16% below the floor baseline: a real regression
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shard_jps=84.0))
        r = by_key(results, "agg_jobs_per_s")
        assert not r["ok"]
        assert by_key(results, "agg_achieved_rps")["ok"], "other checks unaffected"

    def test_ceiling_fails_above_threshold(self, tmp_path):
        # shed_rate_after 0.6 > 0.5 * 1.15 ceiling
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shed=0.6))
        assert not by_key(results, "shed_rate_after_max")["ok"]
        # 0.55 <= 0.575 stays inside
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shed=0.55))
        assert by_key(results, "shed_rate_after_max")["ok"]

    def test_geomean_aggregates_rows(self, tmp_path):
        files = files_for(tmp_path)
        files["shard"] = write_rows(
            tmp_path, "shard2.json", [{"jobs_per_s": 50.0}, {"jobs_per_s": 200.0}]
        )
        results, _ = bench_gate.run_gate(baseline(), files)
        r = by_key(results, "agg_jobs_per_s")
        assert r["current"] == pytest.approx(100.0)  # sqrt(50 * 200)
        assert r["rows"] == 2

    def test_qos_per_class_rows_aggregate_and_pass(self, tmp_path):
        # geomean over the per-class rps rows; max over share_err rows
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path))
        rps = by_key(results, "agg_qos_rps")
        assert rps["ok"]
        assert rps["current"] == pytest.approx(50.0)  # cbrt(100 * 25 * 50)
        assert rps["rows"] == 3
        err = by_key(results, "share_err_max")
        assert err["ok"]
        assert err["current"] == pytest.approx(0.05), "max across class rows"

    def test_fully_starved_class_fails_the_floor(self, tmp_path):
        # a zero-throughput row must collapse the geomean to 0, not be
        # dropped from it — one starved class fails the gate
        files = files_for(tmp_path)
        files["qos"] = write_rows(
            tmp_path,
            "starved.json",
            [
                {"class": "gold", "achieved_rps": 500.0, "share_err": 0.05},
                {"class": "bronze", "achieved_rps": 0.0, "share_err": 0.111},
            ],
        )
        results, _ = bench_gate.run_gate(baseline(), files)
        r = by_key(results, "agg_qos_rps")
        assert r["current"] == 0.0
        assert not r["ok"]

    def test_qos_throughput_floor_trips(self, tmp_path):
        # 20% below the committed per-class throughput floor
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, qos_rps=40.0))
        assert not by_key(results, "agg_qos_rps")["ok"]
        assert by_key(results, "share_err_max")["ok"], "conformance unaffected"

    def test_qos_share_conformance_ceiling_trips(self, tmp_path):
        # a 0.3 worst-class share error breaches the 0.2 * 1.15 ceiling
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, share_err=0.3))
        assert not by_key(results, "share_err_max")["ok"]
        # 0.22 <= 0.23 stays inside
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, share_err=0.22))
        assert by_key(results, "share_err_max")["ok"]

    def test_backend_routed_throughput_floor_trips(self, tmp_path):
        # geomean over the per-config rows (40, 160, 80) = 80 < 85 floor
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, routed_rps=80.0))
        assert not by_key(results, "agg_routed_rps")["ok"]
        assert by_key(results, "validate_overhead_max")["ok"], "overhead unaffected"

    def test_largefft_rows_aggregate_and_pass(self, tmp_path):
        # geomean over the per-size/per-strategy mp_rps rows
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path))
        r = by_key(results, "agg_mp_rps")
        assert r["ok"]
        assert r["current"] == pytest.approx(2.0)  # cbrt(4 * 1 * 2)
        assert r["rows"] == 3

    def test_largefft_throughput_floor_trips(self, tmp_path):
        # geomean 0.5 is far below the committed 1.0 floor
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, mp_rps=0.5))
        assert not by_key(results, "agg_mp_rps")["ok"]
        assert by_key(results, "agg_jobs_per_s")["ok"], "other floors unaffected"

    def test_hotpath_rows_aggregate_and_pass(self, tmp_path):
        # max over the per-config ns_per_job rows, ceiling direction
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path))
        r = by_key(results, "ns_per_job_max")
        assert r["ok"]
        assert r["current"] == pytest.approx(50000.0), "max across config rows"
        assert r["rows"] == 2

    def test_hotpath_dispatch_overhead_ceiling_trips(self, tmp_path):
        # 120µs/job breaches the 100µs * 1.15 committed ceiling
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, ns_per_job=120000.0)
        )
        assert not by_key(results, "ns_per_job_max")["ok"]
        assert by_key(results, "agg_jobs_per_s")["ok"], "other checks unaffected"
        # 110µs <= 115µs stays inside
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, ns_per_job=110000.0)
        )
        assert by_key(results, "ns_per_job_max")["ok"]

    def test_backend_validate_overhead_ceiling_trips(self, tmp_path):
        # 0.5 breaches the 0.4 * 1.15 committed ceiling
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, overhead=0.5))
        assert not by_key(results, "validate_overhead_max")["ok"]
        assert by_key(results, "agg_routed_rps")["ok"], "throughput unaffected"
        # 0.45 <= 0.46 stays inside
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, overhead=0.45))
        assert by_key(results, "validate_overhead_max")["ok"]

    def test_tenants_rows_aggregate_and_pass(self, tmp_path):
        # geomean over the per-tenant adversarial completion rates; max
        # over the interference rows reads only the victim (abuser = 0)
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path))
        rps = by_key(results, "agg_tenant_rps")
        assert rps["ok"]
        assert rps["current"] == pytest.approx(100.0)  # sqrt(50 * 200)
        assert rps["rows"] == 2
        interference = by_key(results, "p99_interference_max")
        assert interference["ok"]
        assert interference["current"] == pytest.approx(2.0), "victim row only"

    def test_tenants_throughput_floor_trips(self, tmp_path):
        # geomean 40 is below the 50 * 0.85 committed floor
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, tenant_rps=40.0)
        )
        assert not by_key(results, "agg_tenant_rps")["ok"]
        assert by_key(results, "p99_interference_max")["ok"], "isolation unaffected"

    def test_tenants_interference_ceiling_trips(self, tmp_path):
        # a 10x victim-p99 blowup breaches the 8.0 * 1.15 ceiling — the
        # abuser leaked through the token bucket into the victim's queue
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, interference=10.0)
        )
        assert not by_key(results, "p99_interference_max")["ok"]
        assert by_key(results, "agg_tenant_rps")["ok"], "throughput unaffected"
        # 9.0 <= 9.2 stays inside
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, interference=9.0)
        )
        assert by_key(results, "p99_interference_max")["ok"]

    def test_ntt_rows_aggregate_and_pass(self, tmp_path):
        # geomean over the per-config serving rates (200, 100, 50)
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path))
        r = by_key(results, "agg_ntt_rps")
        assert r["ok"]
        assert r["current"] == pytest.approx(100.0)  # cbrt(200 * 100 * 50)
        assert r["rows"] == 3

    def test_ntt_throughput_floor_trips(self, tmp_path):
        # geomean 40 is below the 50 * 0.85 committed floor
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, ntt_rps=40.0))
        assert not by_key(results, "agg_ntt_rps")["ok"]
        assert by_key(results, "agg_jobs_per_s")["ok"], "other floors unaffected"

    def test_stalled_ntt_leg_fails_the_floor(self, tmp_path):
        # a zero-throughput leg (e.g. the multipass path wedged) must
        # collapse the geomean to 0, not be dropped from it
        files = files_for(tmp_path)
        files["ntt"] = write_rows(
            tmp_path,
            "stalled_ntt.json",
            [
                {"config": "saturated_2shard_1024", "ntt_rps": 500.0},
                {"config": "multipass_65536", "ntt_rps": 0.0},
            ],
        )
        results, _ = bench_gate.run_gate(baseline(), files)
        r = by_key(results, "agg_ntt_rps")
        assert r["current"] == 0.0
        assert not r["ok"]

    def test_fully_starved_tenant_fails_the_floor(self, tmp_path):
        # a tenant served nothing in the adversarial phase collapses the
        # geomean to 0 — isolation that starves the victim is a failure
        files = files_for(tmp_path)
        files["tenants"] = write_rows(
            tmp_path,
            "starved_tenant.json",
            [
                {"tenant": "victim", "tenant_rps": 0.0, "p99_interference": 1.0},
                {"tenant": "abuser", "tenant_rps": 500.0, "p99_interference": 0.0},
            ],
        )
        results, _ = bench_gate.run_gate(baseline(), files)
        r = by_key(results, "agg_tenant_rps")
        assert r["current"] == 0.0
        assert not r["ok"]


class TestMissingInputs:
    def test_rows_missing_the_field_raise(self, tmp_path):
        files = files_for(tmp_path)
        files["shard"] = write_rows(tmp_path, "bad.json", [{"wrong_field": 1.0}])
        with pytest.raises(SystemExit, match="lack the `jobs_per_s` field"):
            bench_gate.run_gate(baseline(), files)

    def test_empty_rows_raise(self, tmp_path):
        files = files_for(tmp_path)
        files["loadtest"] = write_rows(tmp_path, "empty.json", [])
        with pytest.raises(SystemExit, match="non-empty JSON array"):
            bench_gate.run_gate(baseline(), files)

    def test_gated_section_without_file_raises(self, tmp_path):
        files = files_for(tmp_path)
        files["autoscale"] = None
        with pytest.raises(SystemExit, match="no --autoscale file"):
            bench_gate.run_gate(baseline(), files)

    def test_gated_qos_section_without_file_raises(self, tmp_path):
        files = files_for(tmp_path)
        files["qos"] = None
        with pytest.raises(SystemExit, match="no --qos file"):
            bench_gate.run_gate(baseline(), files)

    def test_qos_rows_missing_share_err_raise(self, tmp_path):
        files = files_for(tmp_path)
        files["qos"] = write_rows(tmp_path, "bad_qos.json", [{"achieved_rps": 50.0}])
        with pytest.raises(SystemExit, match="lack the `share_err` field"):
            bench_gate.run_gate(baseline(), files)

    def test_ungated_section_is_skipped(self, tmp_path):
        # baseline without an autoscale section: no file needed
        files = files_for(tmp_path)
        files["autoscale"] = None
        results, _ = bench_gate.run_gate(baseline(autoscale=False), files)
        assert all(r["section"] != "autoscale" for r in results)

    def test_ungated_qos_section_is_skipped(self, tmp_path):
        files = files_for(tmp_path)
        files["qos"] = None
        results, _ = bench_gate.run_gate(baseline(qos=False), files)
        assert all(r["section"] != "qos" for r in results)

    def test_gated_backend_section_without_file_raises(self, tmp_path):
        files = files_for(tmp_path)
        files["backend"] = None
        with pytest.raises(SystemExit, match="no --backend file"):
            bench_gate.run_gate(baseline(), files)

    def test_ungated_backend_section_is_skipped(self, tmp_path):
        # pre-routing baselines carry no backend section: no file needed
        files = files_for(tmp_path)
        files["backend"] = None
        results, _ = bench_gate.run_gate(baseline(backend=False), files)
        assert all(r["section"] != "backend" for r in results)

    def test_gated_largefft_section_without_file_raises(self, tmp_path):
        files = files_for(tmp_path)
        files["largefft"] = None
        with pytest.raises(SystemExit, match="no --largefft file"):
            bench_gate.run_gate(baseline(), files)

    def test_ungated_largefft_section_is_skipped(self, tmp_path):
        # pre-multipass baselines carry no largefft section
        files = files_for(tmp_path)
        files["largefft"] = None
        results, _ = bench_gate.run_gate(baseline(largefft=False), files)
        assert all(r["section"] != "largefft" for r in results)

    def test_gated_hotpath_section_without_file_raises(self, tmp_path):
        files = files_for(tmp_path)
        files["hotpath"] = None
        with pytest.raises(SystemExit, match="no --hotpath file"):
            bench_gate.run_gate(baseline(), files)

    def test_ungated_hotpath_section_is_skipped(self, tmp_path):
        # pre-arena baselines carry no hotpath section
        files = files_for(tmp_path)
        files["hotpath"] = None
        results, _ = bench_gate.run_gate(baseline(hotpath=False), files)
        assert all(r["section"] != "hotpath" for r in results)

    def test_gated_tenants_section_without_file_raises(self, tmp_path):
        files = files_for(tmp_path)
        files["tenants"] = None
        with pytest.raises(SystemExit, match="no --tenants file"):
            bench_gate.run_gate(baseline(), files)

    def test_ungated_tenants_section_is_skipped(self, tmp_path):
        # pre-tenancy baselines carry no tenants section
        files = files_for(tmp_path)
        files["tenants"] = None
        results, _ = bench_gate.run_gate(baseline(tenants=False), files)
        assert all(r["section"] != "tenants" for r in results)

    def test_gated_ntt_section_without_file_raises(self, tmp_path):
        files = files_for(tmp_path)
        files["ntt"] = None
        with pytest.raises(SystemExit, match="no --ntt file"):
            bench_gate.run_gate(baseline(), files)

    def test_ungated_ntt_section_is_skipped(self, tmp_path):
        # pre-NTT baselines carry no ntt section
        files = files_for(tmp_path)
        files["ntt"] = None
        results, _ = bench_gate.run_gate(baseline(ntt=False), files)
        assert all(r["section"] != "ntt" for r in results)

    def test_tenants_rows_missing_interference_raise(self, tmp_path):
        files = files_for(tmp_path)
        files["tenants"] = write_rows(
            tmp_path, "bad_tenants.json", [{"tenant": "victim", "tenant_rps": 10.0}]
        )
        with pytest.raises(SystemExit, match="lack the `p99_interference` field"):
            bench_gate.run_gate(baseline(), files)


class TestRatchet:
    def test_floor_ratchets_up_to_80_percent_of_observed(self, tmp_path):
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shard_jps=1000.0))
        r = by_key(results, "agg_jobs_per_s")
        assert r["stale"], "10x above the floor is >2x stale"
        assert bench_gate.suggest(r) == pytest.approx(800.0)

    def test_floor_never_ratchets_down(self, tmp_path):
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shard_jps=90.0))
        r = by_key(results, "agg_jobs_per_s")
        assert not r["stale"]
        assert bench_gate.suggest(r) == pytest.approx(100.0), "keeps the committed floor"

    def test_ceiling_tightens_but_keeps_a_guard_band(self, tmp_path):
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shed=0.1))
        r = by_key(results, "shed_rate_after_max")
        assert bench_gate.suggest(r) == pytest.approx(0.125), "1.25x observed"
        # a perfect 0.0 observation must not weld the gate shut
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, shed=0.0))
        r = by_key(results, "shed_rate_after_max")
        assert bench_gate.suggest(r) == pytest.approx(0.02), "absolute guard minimum"

    def test_ceiling_at_its_guard_minimum_is_never_stale(self, tmp_path):
        # a ceiling already ratcheted to its absolute guard cannot be
        # tightened further: a healthy near-zero run must not flag it
        # stale forever
        base = baseline()
        base["qos"]["share_err_max"] = 0.05  # == RATCHET_CEILING_MIN
        base["autoscale"]["shed_rate_after_max"] = 0.02
        results, _ = bench_gate.run_gate(
            base, files_for(tmp_path, shed=0.001, share_err=0.001)
        )
        assert not by_key(results, "share_err_max")["stale"]
        assert not by_key(results, "shed_rate_after_max")["stale"]
        # above the guard, the staleness signal still fires and is
        # actionable (ratcheting clears it)
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, shed=0.001, share_err=0.001)
        )
        assert by_key(results, "share_err_max")["stale"]

    def test_validate_overhead_ceiling_keeps_its_guard_band(self, tmp_path):
        # a zero-overhead run must leave room for the structural cost of
        # validation sampling, not gate future runs onto zero
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, overhead=0.0))
        r = by_key(results, "validate_overhead_max")
        assert bench_gate.suggest(r) == pytest.approx(0.1), "absolute guard minimum"
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, overhead=0.2))
        r = by_key(results, "validate_overhead_max")
        assert bench_gate.suggest(r) == pytest.approx(0.25), "1.25x observed"

    def test_share_err_ceiling_keeps_its_guard_band(self, tmp_path):
        # perfectly fair shares must not ratchet the conformance gate
        # onto zero tolerance
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, share_err=0.0))
        r = by_key(results, "share_err_max")
        assert bench_gate.suggest(r) == pytest.approx(0.05), "absolute guard minimum"
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, share_err=0.1))
        r = by_key(results, "share_err_max")
        assert bench_gate.suggest(r) == pytest.approx(0.125), "1.25x observed"

    def test_hotpath_ceiling_keeps_its_guard_band(self, tmp_path):
        # a suspiciously fast run must not weld the gate below the
        # structural dispatch cost (channel wakeups + payload memcpy)
        results, _ = bench_gate.run_gate(baseline(), files_for(tmp_path, ns_per_job=1000.0))
        r = by_key(results, "ns_per_job_max")
        assert bench_gate.suggest(r) == pytest.approx(20000.0), "absolute guard minimum"
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, ns_per_job=40000.0)
        )
        r = by_key(results, "ns_per_job_max")
        assert bench_gate.suggest(r) == pytest.approx(50000.0), "1.25x observed"

    def test_interference_ceiling_keeps_its_guard_band(self, tmp_path):
        # near-perfect isolation (victim p99 barely moves under attack)
        # must not ratchet the gate into demanding perfect isolation —
        # scheduling jitter alone can push the ratio past 1x
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, interference=0.5)
        )
        r = by_key(results, "p99_interference_max")
        assert bench_gate.suggest(r) == pytest.approx(3.0), "absolute guard minimum"
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, interference=4.0)
        )
        r = by_key(results, "p99_interference_max")
        assert bench_gate.suggest(r) == pytest.approx(5.0), "1.25x observed"

    def test_interference_ceiling_at_its_guard_is_never_stale(self, tmp_path):
        # a committed 8.0 ceiling with 1x observed isolation is stale
        # and actionable; once ratcheted to the 3.0 guard it is not
        results, _ = bench_gate.run_gate(
            baseline(), files_for(tmp_path, interference=1.0)
        )
        assert by_key(results, "p99_interference_max")["stale"]
        base = baseline()
        base["tenants"]["p99_interference_max"] = 3.0
        results, _ = bench_gate.run_gate(base, files_for(tmp_path, interference=1.0))
        assert not by_key(results, "p99_interference_max")["stale"]

    def test_ceiling_guard_is_stable_across_repeated_ratchets(self, tmp_path):
        # repeated lucky-zero observations must converge to the absolute
        # minimum, not decay geometrically toward zero
        base = baseline()
        for _ in range(3):
            results, _ = bench_gate.run_gate(base, files_for(tmp_path, shed=0.0, p99=0.0))
            base = bench_gate.ratchet_baseline(base, results)
        assert base["autoscale"]["shed_rate_after_max"] == pytest.approx(0.02)
        assert base["autoscale"]["p99_recovery_ms_max"] == pytest.approx(250.0)

    def test_ratchet_baseline_preserves_structure(self, tmp_path):
        base = baseline()
        results, _ = bench_gate.run_gate(base, files_for(tmp_path, shard_jps=1000.0))
        out = bench_gate.ratchet_baseline(base, results)
        assert out["shard"]["agg_jobs_per_s"] == pytest.approx(800.0)
        assert out["threshold"] == 0.15
        assert "suggested baseline" in out["_comment"].lower()
        assert base["shard"]["agg_jobs_per_s"] == 100.0, "input baseline untouched"


class TestMain:
    def argv(self, tmp_path, files, extra=()):
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(baseline()))
        return [
            "--baseline",
            str(base_path),
            "--shard",
            files["shard"],
            "--loadtest",
            files["loadtest"],
            "--autoscale",
            files["autoscale"],
            "--qos",
            files["qos"],
            "--backend",
            files["backend"],
            "--largefft",
            files["largefft"],
            "--hotpath",
            files["hotpath"],
            "--tenants",
            files["tenants"],
            "--ntt",
            files["ntt"],
            *extra,
        ]

    def test_main_passes_and_emits_ratchet(self, tmp_path, capsys):
        out_path = tmp_path / "suggested.json"
        files = files_for(tmp_path, shard_jps=1000.0)
        bench_gate.main(self.argv(tmp_path, files, ["--emit-ratchet", str(out_path)]))
        captured = capsys.readouterr().out
        assert "bench-gate passed" in captured
        assert ">2x stale" in captured
        suggested = json.loads(out_path.read_text())
        assert suggested["shard"]["agg_jobs_per_s"] == pytest.approx(800.0)

    def test_main_exits_nonzero_on_regression(self, tmp_path, capsys):
        files = files_for(tmp_path, shard_jps=10.0)
        with pytest.raises(SystemExit) as exc:
            bench_gate.main(self.argv(tmp_path, files))
        assert exc.value.code == 1
        assert "REGRESSION" in capsys.readouterr().out

    def test_main_writes_github_step_summary(self, tmp_path, monkeypatch, capsys):
        summary = tmp_path / "summary.md"
        monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
        files = files_for(tmp_path, shard_jps=1000.0)
        bench_gate.main(self.argv(tmp_path, files))
        capsys.readouterr()
        text = summary.read_text()
        assert "## bench-gate" in text
        assert "stale" in text
        assert "shard.jobs_per_s" in text

    def test_gate_mode_still_requires_the_tier1_bench_files(self, tmp_path, capsys):
        # --shard/--loadtest are optional at the argparse layer (the
        # merge mode needs neither) but gate mode must still demand them
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(baseline()))
        with pytest.raises(SystemExit) as exc:
            bench_gate.main(["--baseline", str(base_path)])
        assert exc.value.code == 2
        err = capsys.readouterr().err
        assert "--shard" in err
        assert "--loadtest" in err


class TestMerge:
    """The --merge-artifact mode: applying a downloaded
    suggested-baseline onto the committed one, monotone in the gate's
    favor."""

    def test_floors_only_ever_rise(self):
        committed = baseline()
        suggested = baseline()
        suggested["shard"]["agg_jobs_per_s"] = 400.0  # ratcheted up: take it
        suggested["loadtest"]["agg_achieved_rps"] = 50.0  # lower: ignore it
        merged, _ = bench_gate.merge_baselines(committed, suggested)
        assert merged["shard"]["agg_jobs_per_s"] == pytest.approx(400.0)
        assert merged["loadtest"]["agg_achieved_rps"] == pytest.approx(200.0)
        assert committed["shard"]["agg_jobs_per_s"] == 100.0, "input untouched"

    def test_ceilings_only_ever_fall_and_respect_the_guard(self):
        committed = baseline()
        suggested = baseline()
        suggested["hotpath"]["ns_per_job_max"] = 50000.0  # tightened: take it
        suggested["autoscale"]["shed_rate_after_max"] = 0.9  # looser: ignore it
        # a suggested value below the absolute guard is clamped onto it
        suggested["tenants"]["p99_interference_max"] = 0.1
        merged, _ = bench_gate.merge_baselines(committed, suggested)
        assert merged["hotpath"]["ns_per_job_max"] == pytest.approx(50000.0)
        assert merged["autoscale"]["shed_rate_after_max"] == pytest.approx(0.5)
        assert merged["tenants"]["p99_interference_max"] == pytest.approx(3.0)

    def test_threshold_and_comment_keep_the_committed_values(self):
        committed = baseline()
        committed["_comment"] = "hand-written envelope rationale"
        suggested = baseline(threshold=0.5)
        suggested["_comment"] = "Suggested baseline emitted by --emit-ratchet"
        merged, _ = bench_gate.merge_baselines(committed, suggested)
        assert merged["threshold"] == 0.15
        assert merged["_comment"] == "hand-written envelope rationale"

    def test_unknown_keys_are_ignored_with_a_note(self):
        committed = baseline()
        suggested = baseline()
        suggested["qos"]["made_up_metric"] = 7.0
        suggested["bogus_section"] = "not even a dict"
        merged, notes = bench_gate.merge_baselines(committed, suggested)
        assert "made_up_metric" not in merged["qos"]
        assert "bogus_section" not in merged
        assert any("made_up_metric" in n for n in notes)
        assert any("bogus_section" in n for n in notes)

    def test_newly_gated_metrics_are_added_with_a_note(self):
        # a committed baseline predating the tenants bench gains the
        # section from the artifact instead of silently dropping it
        committed = baseline(tenants=False)
        suggested = baseline()
        merged, notes = bench_gate.merge_baselines(committed, suggested)
        assert merged["tenants"]["agg_tenant_rps"] == pytest.approx(50.0)
        assert merged["tenants"]["p99_interference_max"] == pytest.approx(8.0)
        assert any("tenants.agg_tenant_rps" in n for n in notes)

    def test_main_merge_mode_prints_json_and_skips_the_gate(
        self, tmp_path, monkeypatch, capsys
    ):
        # no bench files are given: merge mode must not try to gate
        summary = tmp_path / "summary.md"
        monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
        base_path = tmp_path / "baseline.json"
        base_path.write_text(json.dumps(baseline()))
        suggested = baseline()
        suggested["shard"]["agg_jobs_per_s"] = 640.0
        suggested["qos"]["made_up_metric"] = 1.0
        art_path = tmp_path / "suggested.json"
        art_path.write_text(json.dumps(suggested))
        bench_gate.main(
            ["--baseline", str(base_path), "--merge-artifact", str(art_path)]
        )
        captured = capsys.readouterr()
        merged = json.loads(captured.out)
        assert merged["shard"]["agg_jobs_per_s"] == pytest.approx(640.0)
        assert "made_up_metric" in captured.err
        text = summary.read_text()
        assert "## bench-gate baseline merge" in text
        assert '"agg_jobs_per_s": 640.0' in text
