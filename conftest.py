"""Repo-root pytest config: make `compile.*` importable when pytest is
invoked as `pytest python/tests/` from the repository root (the Makefile
runs it from `python/`, where the package is already on sys.path)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))
